"""Mixture-of-Experts with sort-based (scatter/gather) dispatch.

Why not the classic one-hot dispatch einsum: `[B,S,E,C] x [B,S,D]` costs
O(B*S^2*k*D) real matmul FLOPs and would dominate the roofline at 4k+
sequence lengths. Here dispatch is a sort + scatter (bytes, not FLOPs), and
expert compute is a ragged-padded batched matmul `[E,G,D] x [E,D,F]` whose
FLOPs are exactly active-expert FLOPs x capacity_factor — what a production
grouped-GEMM (megablox) implementation costs.

Sharding: expert tensors are sharded on the expert axis when E >= the model
axis size (olmoe: 64e -> 4/device), else on d_ff within each expert
(mixtral: 8e, TP-2 per expert pair). Chosen by launch/shardings.py.

HADES hook: `expert_counts` (tokens routed per expert this step) is returned
as the expert-level access bitmap — the frontend's Object Collector consumes
it to classify hot/cold experts (DESIGN.md §3.1).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Optional activation-sharding hints (§Perf cell A). jit in_shardings only
# pin ARGUMENTS; XLA picks intermediate shardings itself and (measured:
# iteration 1) ignores weight-spec nudges inside the scanned body. These
# with_sharding_constraint hints pin the dispatched-token tensors so the
# partitioner must all-gather WEIGHTS (layer-sized) instead of
# all-reducing partial sums of ACTIVATIONS (batch*seq*d_ff-sized).
# ---------------------------------------------------------------------------
_SHARDING_HINTS = None


def set_sharding_hints(hints) -> None:
    """hints: {"dispatch": PartitionSpec for [E,G,D]-like tensors,
    "hidden": PartitionSpec for [E,G,F]} or None to disable."""
    global _SHARDING_HINTS
    _SHARDING_HINTS = hints


def _hint(x, name):
    if _SHARDING_HINTS and name in _SHARDING_HINTS:
        return jax.lax.with_sharding_constraint(x, _SHARDING_HINTS[name])
    return x


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e = cfg.d_model, cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }


def capacity(t: int, cfg: ModelConfig,
             capacity_factor: float = 1.25) -> int:
    """Per-expert token capacity G used by `moe_block`'s dispatch for `t`
    tokens — the drop threshold: a layer whose routing counts exceed it
    silently drops the overflow (their contribution is zero; the residual
    stream carries them). Exposed so tests/diagnostics can attribute
    decode/prefill divergence to capacity drops."""
    e, k = cfg.num_experts, cfg.experts_per_token
    g = int(max(8, -(-t * k // e) * capacity_factor))  # ceil with slack
    return -(-g // 8) * 8                              # pad to 8


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar, expert_counts [E]).

    Top-k routing with softmax-renormalized weights; sort-based dispatch
    into a [E, G, D] buffer (G = per-expert capacity); tokens over capacity
    are dropped (their contribution is zero — residual stream carries them).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    topk_w, topk_e = jax.lax.top_k(gates, k)                   # [T, k]
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)

    # ---- load-balancing aux loss (Switch/Mixtral style) ----
    me = jnp.mean(gates, axis=0)                               # [E]
    ce = jnp.zeros((e,), jnp.float32).at[topk_e.reshape(-1)].add(1.0) / (t * k)
    aux_loss = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    n = t * k
    flat_e = topk_e.reshape(n)                                 # expert id per slot
    flat_w = topk_w.reshape(n)
    flat_tok = jnp.repeat(jnp.arange(t), k)                    # token id per slot
    order = jnp.argsort(flat_e)                                # stable
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]

    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)      # [E]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n, dtype=jnp.int32) - starts[se]         # intra-expert rank

    g = capacity(t, cfg, capacity_factor)
    keep = rank < g
    dest = jnp.where(keep, se * g + rank, n)                   # n = drop bin

    # scatter tokens -> [E*G, D] (extra row absorbs drops, then sliced off)
    buf = jnp.zeros((e * g + 1, d), x.dtype).at[dest].set(xf[st], mode="drop")
    buf = _hint(buf[:-1].reshape(e, g, d), "dispatch")

    # ---- expert compute (grouped GEMM) ----
    h = _hint(jnp.einsum("egd,edf->egf", buf, p["wi"]), "hidden")
    gate = _hint(jnp.einsum("egd,edf->egf", buf, p["wg"]), "hidden")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype) * h
    y = _hint(jnp.einsum("egf,efd->egd", h, p["wo"]),
              "dispatch").reshape(e * g, d)

    # ---- gather back + weighted combine over k ----
    src = jnp.where(keep, se * g + rank, 0)
    contrib = y[src] * jnp.where(keep, sw, 0.0)[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[st].add(contrib)
    return out.reshape(b, s, d), aux_loss, counts


def moe_block_gathered(p: dict, x: jax.Array, cfg: ModelConfig
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode-path MoE: gather ONLY the routed experts' weights (the
    HADES hot-expert principle applied to the weight stream). Exact —
    same math as moe_block with no capacity drops. Profitable when
    T*k < E (e.g. batch-1 long-context decode); the dense/dispatch path
    wins for large T.

    x: [B, S, D] with small T = B*S."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_e = jax.lax.top_k(gates, k)                  # [T, k]
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)

    wi = p["wi"][topk_e]                                      # [T, k, D, F]
    wg = p["wg"][topk_e]
    wo = p["wo"][topk_e]                                      # [T, k, F, D]
    h = jnp.einsum("td,tkdf->tkf", xf, wi)
    g = jnp.einsum("td,tkdf->tkf", xf, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    y = jnp.einsum("tkf,tkfd->tkd", h, wo)
    out = jnp.einsum("tk,tkd->td", topk_w.astype(y.dtype), y)
    counts = jnp.zeros((e,), jnp.int32).at[topk_e.reshape(-1)].add(1)
    return out.reshape(b, s, d), jnp.zeros((), jnp.float32), counts


def moe_block_ref(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: compute every expert densely, combine by top-k gates.
    O(E x full FLOPs) — tiny shapes only. No capacity drops, so it matches
    moe_block exactly only when no token exceeds capacity."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_e = jax.lax.top_k(gates, k)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    w = jnp.zeros_like(gates).at[jnp.arange(gates.shape[0])[:, None],
                                 topk_e].set(topk_w)           # [T, E]
    h = jnp.einsum("td,edf->etf", xf, p["wi"])
    g = jnp.einsum("td,edf->etf", xf, p["wg"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    y = jnp.einsum("etf,efd->etd", h, p["wo"])                 # [E, T, D]
    out = jnp.einsum("te,etd->td", w.astype(y.dtype), y)
    return out.reshape(b, s, d)
