"""Core layers: RMSNorm, MLPs, rotary embeddings (RoPE / M-RoPE / 2d-RoPE),
embeddings and the logits head.

All matmul weights are stored bf16 (cfg.dtype); norm/softmax/rotary run in
fp32 and cast back. Layers are pure functions over explicit param pytrees so
they compose with lax.scan / jax.checkpoint / shard_map.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(orig)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["wg"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp(p: dict, x: jax.Array, gated: bool) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if gated:
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for a rotary dim (must be even)."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Apply rotation given per-position angles [..., dim/2] to x [..., dim]."""
    orig = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(orig)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE. x: [B, S, H, D]; positions: [B, S] int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [d/2]
    ang = positions[..., None].astype(jnp.float32) * inv   # [B, S, d/2]
    return _rotate(x, ang[:, :, None, :])


def apply_rope2d(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """ChatGLM-style partial rotary: rotate the first half of head_dim with
    the primary position stream; leave the second half unrotated.
    positions: [B, S] (block position stream folded into primary for the
    text backbone)."""
    d = x.shape[-1]
    half = d // 2
    xr, xp = x[..., :half], x[..., half:]
    inv = rope_freqs(half, theta)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([_rotate(xr, ang[:, :, None, :]), xp], axis=-1)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int] = (2, 1, 1)) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim/2 frequency lanes are partitioned into
    (temporal, height, width) sections; each section uses its own position
    stream. positions: [3, B, S] (text tokens use t==h==w).
    `sections` are relative proportions; scaled to d/2 lanes."""
    d = x.shape[-1]
    lanes = d // 2
    total = sum(sections)
    sizes = [lanes * s // total for s in sections]
    sizes[0] = lanes - sizes[1] - sizes[2]
    inv = rope_freqs(d, theta)                        # [lanes]
    pos = positions.astype(jnp.float32)               # [3, B, S]
    # build per-lane position by section
    sec_id = jnp.concatenate([
        jnp.full((sizes[0],), 0), jnp.full((sizes[1],), 1),
        jnp.full((sizes[2],), 2)]).astype(jnp.int32)  # [lanes]
    pos_lanes = jnp.take(pos, sec_id, axis=0)         # [lanes, B, S] -> gather over section
    # pos_lanes: [lanes, B, S] -> [B, S, lanes]
    pos_lanes = jnp.moveaxis(pos_lanes, 0, -1)
    ang = pos_lanes * inv                             # [B, S, lanes]
    return _rotate(x, ang[:, :, None, :])


def positional(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Dispatch on cfg.rope_style. positions: [B,S] or [3,B,S] for mrope."""
    if cfg.rope_style == "none":
        return x
    if cfg.rope_style == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope_style == "rope2d":
        return apply_rope2d(x, positions, cfg.rope_theta)
    if cfg.rope_style == "mrope":
        if positions.ndim == 2:  # text-only stub: t == h == w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.rope_theta)
    raise ValueError(cfg.rope_style)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def logits_head(table_out: jax.Array, x: jax.Array) -> jax.Array:
    """x: [..., D]; table_out: [D, V] -> [..., V] in fp32."""
    return jnp.einsum("...d,dv->...v", x, table_out).astype(jnp.float32)
