"""Selective state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD
(zamba2), with sequence-parallel chunked scans for training/prefill and O(1)
single-step updates for decode.

Training scan strategy (pure JAX; the Pallas `mamba_scan` kernel mirrors it):
  * mamba1: recurrence h_t = a_t*h_{t-1} + b_t runs as lax.scan over chunks
    with a within-chunk associative scan — transient memory is
    O(B*chunk*Din*N) instead of O(B*S*Din*N).
  * mamba2 (SSD): block decomposition into intra-chunk matmuls + inter-chunk
    state carry — all MXU-shaped einsums.

Decode state per layer: {"h": [B, ...states...], "conv": [B, K-1, Din]}.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

MAMBA2_HEADDIM = 64


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def init_mamba1(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = d * cfg.ssm_expand
    n = cfg.ssm_state_dim
    k_conv = cfg.ssm_conv_dim
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * din)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (k_conv, din)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": (jax.random.normal(ks[2], (din, r + 2 * n)) * din ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (r, din)) * r ** -0.5).astype(dtype),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (din,),
                    minval=math.log(1e-3), maxval=math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (din, n))),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (din, d)) * din ** -0.5).astype(dtype),
    }


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = d * cfg.ssm_expand
    n = cfg.ssm_state_dim
    nh = din // MAMBA2_HEADDIM
    k_conv = cfg.ssm_conv_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    conv_dim = din + 2 * n  # conv runs over (x, B, C)
    return {
        "in_proj": (jax.random.normal(
            ks[0], (d, 2 * din + 2 * n + nh)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (k_conv, conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (nh,), minval=1.0, maxval=16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (nh,),
                    minval=math.log(1e-3), maxval=math.log(1e-1))))).astype(jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[0], (din, d)) * din ** -0.5).astype(dtype),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv1d
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, C]; w: [K, C]; state: [B, K-1, C] (decode) or None (train
    — zero history). Returns (y [B,S,C], new_state [B,K-1,C])."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------
def _m1_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
             chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t * h_{t-1} + b_t. a, b: [B, S, C, N];
    h0: [B, C, N]. Returns (h_all [B,S,C,N], h_last)."""
    bsz, s, c, n = a.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    ac = jnp.moveaxis(a.reshape(bsz, nc, chunk, c, n), 1, 0)
    bc = jnp.moveaxis(b.reshape(bsz, nc, chunk, c, n), 1, 0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        ai, bi = xs                                       # [B, chunk, C, N]
        aa, bb = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h_all = aa * h[:, None] + bb                      # prefix applied
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(body, h0, (ac, bc))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(bsz, s, c, n)
    return h_all, h_last


def mamba1_forward(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   chunk: int = 256,
                   state: Dict[str, jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D]. state (decode continuation) or None (from zeros).
    Returns (y [B,S,D], new_state)."""
    bsz, s, d = x.shape
    din = d * cfg.ssm_expand
    n = cfg.ssm_state_dim
    r = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xr, z = jnp.split(xz, 2, axis=-1)                     # [B,S,Din] each
    conv_state = None if state is None else state["conv"]
    xr, new_conv = causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)
    xr = jax.nn.silu(xr.astype(jnp.float32)).astype(xz.dtype)

    proj = jnp.einsum("bsc,ce->bse", xr, p["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jnp.einsum("bsr,rc->bsc", dt, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])               # [B,S,Din]
    a = -jnp.exp(p["A_log"])                              # [Din, N]
    da = jnp.exp(dt[..., None] * a)                       # [B,S,Din,N]
    db = (dt * xr.astype(jnp.float32))[..., None] * \
        bmat.astype(jnp.float32)[:, :, None, :]           # [B,S,Din,N]

    h0 = jnp.zeros((bsz, din, n), jnp.float32) if state is None else state["h"]
    h_all, h_last = _m1_scan(da, db, h0, chunk)
    y = jnp.einsum("bscn,bsn->bsc", h_all,
                   cmat.astype(jnp.float32))              # [B,S,Din]
    y = y + xr.astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"h": h_last, "conv": new_conv}


def mamba1_step(p: dict, x: jax.Array, cfg: ModelConfig,
                state: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode: x [B, 1, D] -> (y [B, 1, D], new_state). O(1) in seq."""
    return mamba1_forward(p, x, cfg, chunk=1, state=state)


def mamba1_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    din = cfg.d_model * cfg.ssm_expand
    return {
        "h": jnp.zeros((batch, din, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, din), dtype),
    }


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------
def mamba2_forward(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   chunk: int = 128,
                   state: Dict[str, jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """SSD block decomposition. x: [B, S, D]."""
    bsz, s, d = x.shape
    din = d * cfg.ssm_expand
    n = cfg.ssm_state_dim
    ph = MAMBA2_HEADDIM
    nh = din // ph

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(zxbcdt.dtype)
    xr, bmat, cmat = jnp.split(xbc, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["A_log"])                                     # [H]
    xh = xr.reshape(bsz, s, nh, ph)
    bf = bmat.astype(jnp.float32)                                # [B,S,N]
    cf = cmat.astype(jnp.float32)

    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    # reshape to chunks
    dtc = dt.reshape(bsz, nc, chunk, nh)
    xc = xh.reshape(bsz, nc, chunk, nh, ph).astype(jnp.float32)
    bc = bf.reshape(bsz, nc, chunk, n)
    cc = cf.reshape(bsz, nc, chunk, n)

    da = dtc * a                                                # [B,NC,L,H]
    cum = jnp.cumsum(da, axis=2)                                # within-chunk
    seg = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,NC,L,L,H]
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    seg = jnp.where(causal[None, None, :, :, None], seg, 0.0)
    cb = jnp.einsum("bzln,bzmn->bzlm", cc, bc)                  # [B,NC,L,L]
    dtx = dtc[..., None] * xc                                   # [B,NC,L,H,P]
    y_intra = jnp.einsum("bzlm,bzlmh,bzmhp->bzlhp", cb, seg, dtx)

    # chunk-final states: S_z = sum_m exp(cum_last - cum_m) dt_m B_m x_m
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # [B,NC,L,H]
    sstate = jnp.einsum("bzmn,bzmh,bzmhp->bznhp", bc,
                        decay_to_end, dtx)                      # [B,NC,N,H,P]

    # carry states across chunks: S'_{z} = exp(sum da_z) S'_{z-1} + S_z
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                  # [B,NC,H]
    h0 = (jnp.zeros((bsz, n, nh, ph), jnp.float32) if state is None
          else state["h"])

    def body(h, xs):
        dec, snew = xs                                          # [B,H], [B,N,H,P]
        h_in = h
        h = dec[:, None, :, None] * h + snew
        return h, h_in

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    ss_t = jnp.moveaxis(sstate, 1, 0)
    h_last, h_prevs = jax.lax.scan(body, h0, (dec_t, ss_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                       # [B,NC,N,H,P]

    y_inter = jnp.einsum("bzln,bzlh,bznhp->bzlhp",
                         cc, jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(bsz, s, nh, ph)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, s, din)
    # gated RMSNorm (mamba2 norm-before-out_proj)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * (1.0 + p["norm"])
    out = jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"h": h_last, "conv": new_conv}


def mamba2_step(p: dict, x: jax.Array, cfg: ModelConfig,
                state: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    return mamba2_forward(p, x, cfg, chunk=1, state=state)


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    din = cfg.d_model * cfg.ssm_expand
    n = cfg.ssm_state_dim
    nh = din // MAMBA2_HEADDIM
    return {
        "h": jnp.zeros((batch, n, nh, MAMBA2_HEADDIM), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, din + 2 * n), dtype),
    }
