"""Public model facade: one object per architecture wrapping init / forward /
loss / prefill / decode, plus `input_specs` — ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation), used
by the multi-pod dry-run and the launchers.

Modality frontends are STUBS per the assignment: [audio] supplies precomputed
frame embeddings (encoder input), [vlm] supplies precomputed patch embeddings
(prepended to the text stream with M-RoPE positions).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T

VLM_PATCHES = 256  # patch budget for the vision stub (full shapes)


def vlm_patches(seq_len: int) -> int:
    """Patch count for a cell: 256 for full shapes, scaled down for smoke."""
    return min(VLM_PATCHES, max(4, seq_len // 4))


class Model:
    def __init__(self, cfg: ModelConfig, attn_impl: str = "blockwise",
                 remat: str = "none"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat = remat

    # -- parameters ---------------------------------------------------------
    def init(self, key) -> dict:
        return T.init_lm(self.cfg, key)

    def param_specs(self) -> dict:
        """Shape/dtype tree without allocating (for dry-run)."""
        return jax.eval_shape(
            lambda: T.init_lm(self.cfg, jax.random.PRNGKey(0)))

    # -- batches ------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec, for_decode_state: bool = True
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every input of the step function
        selected by shape.mode."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        f32 = jnp.dtype(jnp.float32)
        i32 = jnp.dtype(jnp.int32)
        bf16 = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        if shape.mode == "train":
            batch: Dict = {}
            s_txt = s
            if cfg.frontend == "vision":
                p = vlm_patches(s)
                s_txt = s - p
                batch["extra_embeds"] = sds((b, p, cfg.d_model), bf16)
            if cfg.is_encoder_decoder:
                batch["enc_embeds"] = sds((b, cfg.encoder_seq_len,
                                           cfg.d_model), f32)
            batch["tokens"] = sds((b, s_txt), i32)
            batch["labels"] = sds((b, s_txt), i32)
            return batch
        if shape.mode == "prefill":
            batch = {}
            s_txt = s
            if cfg.frontend == "vision":
                p = vlm_patches(s)
                s_txt = s - p
                batch["extra_embeds"] = sds((b, p, cfg.d_model), bf16)
            if cfg.is_encoder_decoder:
                batch["enc_embeds"] = sds((b, cfg.encoder_seq_len,
                                           cfg.d_model), f32)
            batch["tokens"] = sds((b, s_txt), i32)
            return batch
        if shape.mode == "decode":
            batch = {"tokens": sds((b,), i32)}
            if for_decode_state:
                enc = None
                if cfg.is_encoder_decoder:
                    enc = jax.ShapeDtypeStruct(
                        (b, cfg.encoder_seq_len, cfg.d_model), bf16)
                batch["state"] = jax.eval_shape(
                    lambda e: T.init_decode_state(cfg, b, s, enc_out=e), enc)
            return batch
        raise ValueError(shape.mode)

    def make_inputs(self, shape: ShapeSpec, key) -> Dict[str, jax.Array]:
        """Concrete random inputs matching input_specs (smoke tests)."""
        specs = self.input_specs(shape, for_decode_state=False)
        ks = jax.random.split(key, len(specs))
        out = {}
        for (name, spec), k in zip(sorted(specs.items()), ks):
            if jnp.issubdtype(spec.dtype, jnp.integer):
                out[name] = jax.random.randint(
                    k, spec.shape, 0, self.cfg.vocab_size, dtype=spec.dtype)
            else:
                out[name] = (jax.random.normal(k, spec.shape) * 0.02
                             ).astype(spec.dtype)
        if shape.mode == "decode":
            enc = None
            if self.cfg.is_encoder_decoder:
                enc = (jax.random.normal(
                    ks[0], (shape.global_batch, self.cfg.encoder_seq_len,
                            self.cfg.d_model)) * 0.02).astype(jnp.dtype(self.cfg.dtype))
            out["state"] = T.init_decode_state(
                self.cfg, shape.global_batch, shape.seq_len, enc_out=enc)
        return out

    # -- step functions -----------------------------------------------------
    def forward(self, params, batch) -> Tuple[jax.Array, dict]:
        return T.lm_forward(
            params, self.cfg, batch["tokens"],
            extra_embeds=batch.get("extra_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            attn_impl=self.attn_impl, remat=self.remat)

    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        return T.lm_loss(
            params, self.cfg, batch["tokens"], batch["labels"],
            extra_embeds=batch.get("extra_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            attn_impl=self.attn_impl, remat=self.remat)

    def prefill(self, params, batch):
        logits, aux = T.lm_forward(
            params, self.cfg, batch["tokens"],
            extra_embeds=batch.get("extra_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            attn_impl=self.attn_impl, remat=self.remat, return_cache=False)
        return logits

    def init_decode_state(self, batch: int, max_len: int, enc_out=None):
        return T.init_decode_state(self.cfg, batch, max_len, enc_out=enc_out)

    def decode_step(self, params, state, tokens, **kw):
        return T.lm_decode_step(params, self.cfg, state, tokens, **kw)


def build(arch_id: str, reduced: bool = False, **kw) -> Model:
    from repro.configs import get_config
    return Model(get_config(arch_id, reduced=reduced), **kw)
