"""Paged KV cache managed by the HADES frontend.

The representative framework application of the paper (DESIGN.md §3.1):
decode-time KV blocks are *objects* in a HadesPool — each block is
`block_tokens` of K+V for one layer of one sequence. All reads go through
the object table (the dereference); on TPU the Pallas `paged_attention`
kernel records access bits as a by-product of its DMAs (on CPU the jnp
oracle computes the same bits — interpret-mode kernel emulation is
correctness-only, see `attend`), and the Object Collector densifies hot
blocks (recent windows, attention sinks) into HOT superblocks while cold
prefixes drift to COLD and get paged to host.

Logical object id = ((layer * batch) + seq) * max_blocks + block_idx.
Block tables hold LOGICAL ids; physical slots are resolved through the
pool table right before the kernel — which is what makes migration
transparent to the serving loop (the paper's pointer-update guarantee).

Everything here is functional and jit-safe; the serving loop in
runtime/server.py drives (append -> attend -> record -> collect).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import object_table as ot
from repro.core import pool as pl
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    num_layers: int
    batch: int
    max_blocks: int          # per (layer, sequence)
    block_tokens: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "bfloat16"
    sb_slots: int = 16       # superblock granularity (blocks per madvise)
    slack: float = 1.5

    @property
    def max_objects(self) -> int:
        return self.num_layers * self.batch * self.max_blocks

    @property
    def slot_words(self) -> int:
        return 2 * self.block_tokens * self.num_kv_heads * self.head_dim

    def obj_id(self, layer, seq, block):
        return (layer * self.batch + seq) * self.max_blocks + block

    def pool_config(self) -> pl.PoolConfig:
        return pl.make_config(
            self.max_objects, self.slot_words, sb_slots=self.sb_slots,
            page_slots=max(self.sb_slots // 4, 1), slack=self.slack,
            dtype=self.dtype)


def init(cfg: KVCacheConfig, backend: Optional[be.Backend] = None,
         active: bool = True) -> Dict:
    """Fresh serving state. Pass the tiering backend so its carried
    state (`pool["bstate"]`) is seeded for the fused collect+backend
    path; omit it only when no backend will run (stateless backends
    tolerate the default empty carry). The pool carry also seeds the
    free-slot rings + occupancy counters (docs/allocator.md), so every
    `append_layer` allocation inside the decode scan is O(batch), and
    the server's jitted programs donate the whole carry (the paged pool
    updates in place across decode windows).

    Lanes carry a per-lane lifecycle (`active` [B] bool + per-lane
    `pos`): inactive lanes never append, allocate, or record accesses —
    their attends run over zero keys and return zeros. `active=False`
    starts every lane empty for a continuous-batching driver that
    admits lanes via `admit_lanes` (Server.serve); the default keeps
    every lane live, the fixed-batch `generate` contract."""
    pool = pl.init(cfg.pool_config())
    if backend is not None:
        pool = dict(pool, bstate=backend.init(cfg.pool_config()))
    return {
        "pool": pool,
        # logical block table: -1 = unallocated
        "block_tables": jnp.full(
            (cfg.num_layers, cfg.batch, cfg.max_blocks), -1, jnp.int32),
        "pos": jnp.zeros((cfg.batch,), jnp.int32),
        "active": jnp.full((cfg.batch,), bool(active), jnp.bool_),
    }


# ---------------------------------------------------------------------------
# append — write this step's k/v for ALL layers at the current position
# ---------------------------------------------------------------------------
def append(cfg: KVCacheConfig, state: Dict, k: jax.Array, v: jax.Array
           ) -> Dict:
    """k/v: [L, B, KV, D] (one new token per sequence). A layer-major
    loop over `append_layer` (ONE capacity-guard/overflow-drop
    implementation — the slot assignment is identical either way) plus
    the step's pos advance. Tokens past cfg.max_blocks capacity are
    DROPPED (never written) — an unguarded write would clamp into a live
    object's slot and corrupt another sequence's KV."""
    for li in range(cfg.num_layers):
        state = append_layer(cfg, state, li, k[li], v[li])
    return advance_pos(state)


def append_layer(cfg: KVCacheConfig, state: Dict, layer, k: jax.Array,
                 v: jax.Array) -> Dict:
    """k/v: [B, KV, D] — ONE layer's k/v for the current token, for the
    server's fused per-layer decode transition (qkv -> append -> attend
    with `h` advanced through each layer, which `append` cannot express:
    it needs all layers' k/v up front). `layer` may be a traced index
    (the decode layer scan). Does NOT advance `pos` — the caller calls
    `advance_pos` once per step, after all layers. Slot assignment is
    identical to `append`'s (allocations are layer-major either way);
    tokens past cfg.max_blocks capacity are dropped, like `append`."""
    pcfg = cfg.pool_config()
    pos = state["pos"]                       # [B]
    blk = pos // cfg.block_tokens
    off = pos % cfg.block_tokens
    # capacity guard + lane lifecycle: inactive lanes (no live request
    # on the lane) neither allocate nor write
    fits = (blk < cfg.max_blocks) & state["active"]     # [B]
    b_idx = jnp.arange(cfg.batch)
    obj = ((layer * cfg.batch + b_idx) * cfg.max_blocks + blk
           ).astype(jnp.int32)               # [B]

    need = (off == 0) & fits
    pool = state["pool"]
    zeros = jnp.zeros((cfg.batch, pcfg.slot_words), pool["data"].dtype)
    pool = pl.alloc(pcfg, pool, jnp.where(need, obj, -1), zeros)
    blk_safe = jnp.minimum(blk, cfg.max_blocks - 1)
    bt = state["block_tables"].at[layer, b_idx, blk].set(
        jnp.where(need, obj,
                  state["block_tables"][layer, b_idx, blk_safe]),
        mode="drop")

    words = pool["table"][jnp.minimum(obj, cfg.max_objects - 1)]
    slots = ot.slot_of(words).astype(jnp.int32)         # [B]
    data = pool["data"].reshape(
        -1, 2, cfg.block_tokens, cfg.num_kv_heads, cfg.head_dim)
    # overflow/inactive lanes route out of bounds and are dropped,
    # never clamped
    slots = jnp.where(fits, slots, data.shape[0])
    kv_tok = jnp.stack([k, v], axis=1)        # [B, 2, KV, D]
    data = data.at[slots, :, off, :, :].set(kv_tok.astype(data.dtype),
                                            mode="drop")
    pool = dict(pool, data=data.reshape(pool["data"].shape))
    return dict(state, pool=pool, block_tables=bt)


def advance_pos(state: Dict) -> Dict:
    """One decode step consumed (all layers appended): pos += 1 on
    active lanes; an inactive lane's clock holds at its reset value."""
    return dict(state, pos=state["pos"] + state["active"].astype(jnp.int32))


# ---------------------------------------------------------------------------
# lane lifecycle — continuous batching's finish/refill transitions
# ---------------------------------------------------------------------------
def free_lanes(cfg: KVCacheConfig, state: Dict, lanes: jax.Array) -> Dict:
    """Finish the masked lanes: free ALL their KV objects through the
    pool op stream. lanes: [B] bool.

    The release is ONE batched `pool.free` over every (layer, block)
    object id the lane could own — K = layers * batch * max_blocks ids,
    the O(K) free-ring path (slots push back onto their region's rings,
    `sb_occ` decrements, `slot_ref`/table words clear); ids the lane
    never allocated are dead and dropped by the op, so partially-filled
    lanes free exactly their live blocks. The lane's block-table row
    resets to -1, its pos to 0, and its active bit clears — the freed
    cold blocks are now the fragmentation the collector must tidy so
    the backend can reclaim their superblocks."""
    pcfg = cfg.pool_config()
    li = jnp.arange(cfg.num_layers, dtype=jnp.int32)[:, None, None]
    bi = jnp.arange(cfg.batch, dtype=jnp.int32)[None, :, None]
    ki = jnp.arange(cfg.max_blocks, dtype=jnp.int32)[None, None, :]
    obj = (li * cfg.batch + bi) * cfg.max_blocks + ki   # [L, B, MB]
    ids = jnp.where(lanes[None, :, None], obj, -1).reshape(-1)
    return dict(state,
                pool=pl.free(pcfg, state["pool"], ids),
                block_tables=jnp.where(lanes[None, :, None], -1,
                                       state["block_tables"]),
                pos=jnp.where(lanes, 0, state["pos"]),
                active=state["active"] & ~lanes)


def admit_lanes(state: Dict, lanes: jax.Array) -> Dict:
    """Activate the masked lanes for fresh sequences: pos 0, active set.
    Admit touches no pool state — any previous occupant must already be
    freed (`free_lanes`); a lane may be freed and re-admitted in the
    same window-boundary event."""
    return dict(state,
                pos=jnp.where(lanes, 0, state["pos"]),
                active=state["active"] | lanes)


# ---------------------------------------------------------------------------
# attend — decode attention through the table (Pallas kernel) + tracking
# ---------------------------------------------------------------------------
def attend(cfg: KVCacheConfig, state: Dict, layer: int, q: jax.Array,
           *, seq_lens: Optional[jax.Array] = None,
           use_pallas: Optional[bool] = None) -> Tuple[jax.Array, Dict]:
    """q: [B, H, D] -> (out [B, H, D], state with access recorded).
    `layer` may be a traced index (the server's decode layer scan).
    `seq_lens` defaults to state["pos"] — correct when the caller has
    already advanced pos past the appended token (`append`); the
    per-layer flow (`append_layer`, pos still pointing AT the new token)
    must pass pos + 1 so the token attends to itself.

    `use_pallas=None` picks the implementation by backend, mirroring the
    collector's CollectorConfig(use_pallas) split: the Pallas kernel
    (with its fused access-bit recording) compiles natively on TPU, while
    CPU runs the pure-jnp oracle — interpret-mode kernel emulation is
    correctness-only and orders of magnitude too slow for the serving
    hot path (tests/test_kernels.py keeps the two bit-compatible on the
    touched bits and within fp tolerance on the outputs)."""
    pcfg = cfg.pool_config()
    pool = state["pool"]
    tbl = state["block_tables"][layer]               # [B, MB] logical ids
    live = tbl >= 0
    words = pool["table"][jnp.maximum(tbl, 0)]
    slots = jnp.where(live, ot.slot_of(words).astype(jnp.int32), -1)
    lens = state["pos"] if seq_lens is None else seq_lens
    # inactive lanes attend over zero keys -> zeros out, nothing touched
    lens = jnp.where(state["active"], lens, 0)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    pages = pool["data"].reshape(
        -1, 2, cfg.block_tokens, cfg.num_kv_heads, cfg.head_dim)
    if use_pallas:
        out, touched = kops.paged_attention(
            q, pages[:, 0], pages[:, 1], slots, lens)
    else:
        from repro.kernels import ref as kref
        out, touched = kref.paged_attention(
            q, pages[:, 0], pages[:, 1], slots, lens, cfg.block_tokens)

    # inactive lanes really do return ZEROS: with lens == 0 the kernels'
    # all-masked softmax degenerates to a mean over slot 0's payload (a
    # live neighbor's KV) — mask it out rather than leak it
    out = jnp.where(state["active"][:, None, None], out, 0)
    # the kernel's fused access bits -> object-table access bits
    touched_ids = jnp.where(touched & live & state["active"][:, None],
                            tbl, -1).reshape(-1)
    pool = _record_touched(pcfg, pool, touched_ids)
    return out, dict(state, pool=pool)


def _record_touched(pcfg: pl.PoolConfig, pool: Dict, obj_ids: jax.Array
                    ) -> Dict:
    """pool.read's accounting without the data gather (the kernel already
    did the reads): access bits, ATC when armed, promo/fault counters."""
    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = pool["table"][ids]
    live = ot.is_live(words) & valid
    tbl = ot.record_access(pool["table"], jnp.where(live, obj_ids, -1),
                           armed=pool["armed"])
    slots = ot.slot_of(words).astype(jnp.int32)
    slot_ref = pool["slot_ref"].at[
        jnp.where(live, slots, pcfg.n_slots)].set(True, mode="drop")
    sbs = slots // pcfg.sb_slots
    on_host = live & (pool["sb_tier"][sbs] == pl.HOST)
    fault_mask = jnp.zeros((pcfg.n_sbs,), jnp.bool_).at[
        jnp.where(on_host, sbs, pcfg.n_sbs)].set(True, mode="drop")
    n_faults = jnp.sum(fault_mask).astype(jnp.int32)
    promos = jnp.sum(live & (ot.heap_of(words) == ot.COLD)).astype(jnp.int32)
    return dict(
        pool, table=tbl, slot_ref=slot_ref,
        sb_tier=jnp.where(fault_mask, pl.HBM, pool["sb_tier"]).astype(jnp.int8),
        sb_evict=jnp.where(fault_mask, pl.NORMAL,
                           pool["sb_evict"]).astype(jnp.int8),
        win_accesses=pool["win_accesses"] + jnp.sum(live),
        win_promos=pool["win_promos"] + promos,
        win_faults=pool["win_faults"] + n_faults,
        total_faults=pool["total_faults"] + n_faults)


# ---------------------------------------------------------------------------
# collect — run the Object Collector + backend over the KV pool
# ---------------------------------------------------------------------------
def collect(cfg: KVCacheConfig, state: Dict,
            col_cfg: Optional[col.CollectorConfig] = None
            ) -> Tuple[Dict, Dict]:
    pcfg = cfg.pool_config()
    pool, report = col.collect(pcfg, col_cfg or col.CollectorConfig(),
                               state["pool"])
    return dict(state, pool=pool), report


def collect_and_backend(cfg: KVCacheConfig, col_cfg: col.CollectorConfig,
                        backend: be.Backend, state: Dict
                        ) -> Tuple[Dict, Dict]:
    """Collector + backend over the KV pool as ONE fused transition (the
    engine's serving-window path) — replaces the old collect-dispatch /
    stats-pop / backend-dispatch sequence in the server loop. The
    backend's carried state rides `state["pool"]["bstate"]` through the
    decode-window scan (seed it via `init(cfg, backend=...)`)."""
    pool, report = eng.collect_and_backend(cfg.pool_config(), col_cfg,
                                           backend, state["pool"])
    return dict(state, pool=pool), report


def arm(state: Dict) -> Dict:
    return dict(state, pool=col.arm(state["pool"]))


def kv_bytes(cfg: KVCacheConfig) -> int:
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return cfg.max_objects * cfg.slot_words * itemsize
