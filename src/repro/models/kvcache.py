"""Paged KV cache managed by the HADES frontend.

The representative framework application of the paper (DESIGN.md §3.1):
decode-time KV blocks are *objects* in a HadesPool — each block is
`block_tokens` of K+V for one layer of one sequence. All reads go through
the object table (the dereference), the Pallas `paged_attention` kernel
records access bits as a by-product of its DMAs, and the Object Collector
densifies hot blocks (recent windows, attention sinks) into HOT
superblocks while cold prefixes drift to COLD and get paged to host.

Logical object id = ((layer * batch) + seq) * max_blocks + block_idx.
Block tables hold LOGICAL ids; physical slots are resolved through the
pool table right before the kernel — which is what makes migration
transparent to the serving loop (the paper's pointer-update guarantee).

Everything here is functional and jit-safe; the serving loop in
runtime/server.py drives (append -> attend -> record -> collect).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import object_table as ot
from repro.core import pool as pl
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    num_layers: int
    batch: int
    max_blocks: int          # per (layer, sequence)
    block_tokens: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "bfloat16"
    sb_slots: int = 16       # superblock granularity (blocks per madvise)
    slack: float = 1.5

    @property
    def max_objects(self) -> int:
        return self.num_layers * self.batch * self.max_blocks

    @property
    def slot_words(self) -> int:
        return 2 * self.block_tokens * self.num_kv_heads * self.head_dim

    def obj_id(self, layer, seq, block):
        return (layer * self.batch + seq) * self.max_blocks + block

    def pool_config(self) -> pl.PoolConfig:
        return pl.make_config(
            self.max_objects, self.slot_words, sb_slots=self.sb_slots,
            page_slots=max(self.sb_slots // 4, 1), slack=self.slack,
            dtype=self.dtype)


def init(cfg: KVCacheConfig) -> Dict:
    return {
        "pool": pl.init(cfg.pool_config()),
        # logical block table: -1 = unallocated
        "block_tables": jnp.full(
            (cfg.num_layers, cfg.batch, cfg.max_blocks), -1, jnp.int32),
        "pos": jnp.zeros((cfg.batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# append — write this step's k/v for ALL layers at the current position
# ---------------------------------------------------------------------------
def append(cfg: KVCacheConfig, state: Dict, k: jax.Array, v: jax.Array
           ) -> Dict:
    """k/v: [L, B, KV, D] (one new token per sequence). Allocates fresh
    blocks at block boundaries, then scatters the token into each block's
    slot at the intra-block offset."""
    pcfg = cfg.pool_config()
    pos = state["pos"]                       # [B]
    blk = pos // cfg.block_tokens            # [B]
    off = pos % cfg.block_tokens             # [B]
    l_idx = jnp.arange(cfg.num_layers)[:, None]
    b_idx = jnp.arange(cfg.batch)[None, :]
    obj = ((l_idx * cfg.batch + b_idx) * cfg.max_blocks + blk[None, :]
           ).astype(jnp.int32)               # [L, B]

    # allocate blocks where off == 0 (start of a new block)
    need = jnp.broadcast_to(off[None, :] == 0, obj.shape)
    pool = state["pool"]
    zeros = jnp.zeros((cfg.num_layers * cfg.batch, pcfg.slot_words),
                      pool["data"].dtype)
    pool = pl.alloc(pcfg, pool, jnp.where(need, obj, -1).reshape(-1), zeros)
    bt = state["block_tables"].at[
        l_idx, b_idx, jnp.broadcast_to(blk[None, :], obj.shape)
    ].set(jnp.where(need, obj, state["block_tables"][
        l_idx, b_idx, jnp.broadcast_to(blk[None, :], obj.shape)]))

    # scatter the token into each block slot at offset `off`
    words = pool["table"][obj.reshape(-1)]
    slots = ot.slot_of(words).astype(jnp.int32).reshape(cfg.num_layers,
                                                        cfg.batch)
    data = pool["data"].reshape(
        -1, 2, cfg.block_tokens, cfg.num_kv_heads, cfg.head_dim)
    kv_tok = jnp.stack([k, v], axis=2)        # [L, B, 2, KV, D]
    data = data.at[slots, :, off[None, :], :, :].set(
        kv_tok.astype(data.dtype))
    pool = dict(pool, data=data.reshape(pool["data"].shape))
    return dict(state, pool=pool,
                block_tables=bt, pos=pos + 1)


# ---------------------------------------------------------------------------
# attend — decode attention through the table (Pallas kernel) + tracking
# ---------------------------------------------------------------------------
def attend(cfg: KVCacheConfig, state: Dict, layer: int, q: jax.Array
           ) -> Tuple[jax.Array, Dict]:
    """q: [B, H, D] -> (out [B, H, D], state with access recorded)."""
    pcfg = cfg.pool_config()
    pool = state["pool"]
    tbl = state["block_tables"][layer]               # [B, MB] logical ids
    live = tbl >= 0
    words = pool["table"][jnp.maximum(tbl, 0)]
    slots = jnp.where(live, ot.slot_of(words).astype(jnp.int32), -1)

    pages = pool["data"].reshape(
        -1, 2, cfg.block_tokens, cfg.num_kv_heads, cfg.head_dim)
    out, touched = kops.paged_attention(
        q, pages[:, 0], pages[:, 1], slots, state["pos"])

    # the kernel's fused access bits -> object-table access bits
    touched_ids = jnp.where(touched & live, tbl, -1).reshape(-1)
    pool = _record_touched(pcfg, pool, touched_ids)
    return out, dict(state, pool=pool)


def _record_touched(pcfg: pl.PoolConfig, pool: Dict, obj_ids: jax.Array
                    ) -> Dict:
    """pool.read's accounting without the data gather (the kernel already
    did the reads): access bits, ATC when armed, promo/fault counters."""
    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = pool["table"][ids]
    live = ot.is_live(words) & valid
    tbl = ot.record_access(pool["table"], jnp.where(live, obj_ids, -1),
                           armed=pool["armed"])
    slots = ot.slot_of(words).astype(jnp.int32)
    sbs = slots // pcfg.sb_slots
    on_host = live & (pool["sb_tier"][sbs] == pl.HOST)
    fault_mask = jnp.zeros((pcfg.n_sbs,), jnp.bool_).at[
        jnp.where(on_host, sbs, pcfg.n_sbs)].set(True, mode="drop")
    n_faults = jnp.sum(fault_mask).astype(jnp.int32)
    promos = jnp.sum(live & (ot.heap_of(words) == ot.COLD)).astype(jnp.int32)
    return dict(
        pool, table=tbl,
        sb_tier=jnp.where(fault_mask, pl.HBM, pool["sb_tier"]).astype(jnp.int8),
        sb_evict=jnp.where(fault_mask, pl.NORMAL,
                           pool["sb_evict"]).astype(jnp.int8),
        win_accesses=pool["win_accesses"] + jnp.sum(live),
        win_promos=pool["win_promos"] + promos,
        win_faults=pool["win_faults"] + n_faults,
        total_faults=pool["total_faults"] + n_faults)


# ---------------------------------------------------------------------------
# collect — run the Object Collector + backend over the KV pool
# ---------------------------------------------------------------------------
def collect(cfg: KVCacheConfig, state: Dict,
            col_cfg: Optional[col.CollectorConfig] = None
            ) -> Tuple[Dict, Dict]:
    pcfg = cfg.pool_config()
    pool, report = col.collect(pcfg, col_cfg or col.CollectorConfig(),
                               state["pool"])
    return dict(state, pool=pool), report


def collect_and_backend(cfg: KVCacheConfig, col_cfg: col.CollectorConfig,
                        be_cfg: be.BackendConfig, state: Dict
                        ) -> Tuple[Dict, Dict]:
    """Collector + backend over the KV pool as ONE fused transition (the
    engine's serving-window path) — replaces the old collect-dispatch /
    stats-pop / backend-dispatch sequence in the server loop."""
    pool, report = eng.collect_and_backend(cfg.pool_config(), col_cfg,
                                           be_cfg, state["pool"])
    return dict(state, pool=pool), report


def arm(state: Dict) -> Dict:
    return dict(state, pool=col.arm(state["pool"]))


def kv_bytes(cfg: KVCacheConfig) -> int:
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return cfg.max_objects * cfg.slot_words * itemsize
