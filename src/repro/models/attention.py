"""Attention: full (oracle), blockwise (memory-efficient online-softmax,
the XLA analog of flash attention), sliding-window, decode (single-token
vs a KV cache, with distributed flash-decoding combine), and cross-attention.

Shapes convention:
  q: [B, S, H, D]    k/v: [B, S_kv, KV, D]   (KV = num kv heads, GQA groups
  are expanded inside — H % KV == 0).

`blockwise_attention` is used for training/prefill in the dry-run: it never
materializes the [S, S] score matrix (lax.scan over KV chunks with running
max/denominator), so compile-time memory analysis reflects a production
flash implementation. The Pallas flash kernel (kernels/flash_attention.py)
targets the same math for real TPUs; `full_attention` is the shared oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38  # ~ -bf16 max; matches TPU flash kernels


def _expand_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, KV, D] -> [B, S, KV*n_rep, D] by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)) \
              .reshape(b, s, kv * n_rep, d)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    """Additive mask bias [.., Sq, Sk] from absolute positions."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 jnp.bool_)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    return jnp.where(m, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# Full attention — the oracle (materializes scores; tiny shapes only)
# ---------------------------------------------------------------------------
def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_pos: Optional[jax.Array] = None,
                   k_pos: Optional[jax.Array] = None,
                   softcap: float = 0.0) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    k = _expand_kv(k, h // kv)
    v = _expand_kv(v, h // kv)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= d ** -0.5
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores += _mask_bias(q_pos, k_pos, causal, window)[:, None]
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Blockwise attention — memory-efficient online softmax over KV chunks
# ---------------------------------------------------------------------------
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        chunk: int = 512,
                        q_pos: Optional[jax.Array] = None,
                        k_pos: Optional[jax.Array] = None,
                        softcap: float = 0.0) -> jax.Array:
    """Never materializes [Sq, Sk]: scans KV in chunks of `chunk`, keeping
    running (max, denom, weighted-sum). Live memory O(Sq*chunk)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    n_rep = h // kv
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_pos is None:
            k_pos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)
        sk += pad
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))

    n_chunks = sk // chunk
    kc = k.reshape(b, n_chunks, chunk, kv, d)
    vc = v.reshape(b, n_chunks, chunk, kv, d)
    pc = k_pos.reshape(b, n_chunks, chunk)
    qf = q.astype(jnp.float32) * d ** -0.5

    def body(carry, xs):
        m, l, acc = carry           # [B,H,Sq], [B,H,Sq], [B,Sq,H,D]
        kb, vb, pb = xs             # [B,chunk,KV,D], ..., [B,chunk]
        kb = _expand_kv(kb, n_rep)
        vb = _expand_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s += _mask_bias(q_pos, pb, causal, window)[:, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * jnp.moveaxis(scale, 1, -1)[..., None] + \
            jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, sq, h, d), jnp.float32))
    # scan over chunk axis (moved to front)
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(pc, 1, 0))
    (m, l, acc), _ = jax.lax.scan(body, init, xs)
    l = jnp.maximum(l, 1e-30)
    out = acc / jnp.moveaxis(l, 1, -1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention — one query token vs a KV cache
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0,
                     k_pos: Optional[jax.Array] = None,
                     q_pos: Optional[jax.Array] = None) -> jax.Array:
    """q: [B, 1, H, D]; caches: [B, S, KV, D]; cache_len: scalar or [B]
    number of valid entries. Computes masked softmax over the cache in
    fp32 with one pass (O(S) memory, S x D matvec). Window masking uses
    absolute positions when k_pos is given (ring-buffer caches)."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    k = _expand_kv(k_cache, h // kv)
    v = _expand_kv(v_cache, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= d ** -0.5
    idx = jnp.arange(s)[None]                     # [1, S]
    valid = idx < jnp.reshape(cache_len, (-1, 1))
    if window > 0:
        if q_pos is None:
            q_pos = jnp.reshape(cache_len, (-1, 1)) - 1
        kp = idx if k_pos is None else k_pos
        valid &= kp > jnp.reshape(q_pos, (-1, 1)) - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def decode_attention_partial(q, k_part, v_part, valid_mask):
    """Flash-decoding partial: attention over a shard of the KV sequence.
    Returns (unnormalized_out [B,1,H,D] fp32, m [B,H,1], l [B,H,1]) so that
    shards combine with `combine_partials` (psum-style merge).
    valid_mask: [B, S_part] bool."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_part.shape
    k = _expand_kv(k_part, h // kv)
    v = _expand_kv(v_part, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= d ** -0.5
    scores = jnp.where(valid_mask[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                  # [B,H,1]
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)                       # [B,H,1]
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out, m, l


def combine_partials(parts):
    """Merge flash-decoding partials [(out, m, l)] -> [B,1,H,D]."""
    outs, ms, ls = zip(*parts)
    m_all = jnp.max(jnp.stack(ms), axis=0)
    tot_l = 0.0
    tot_o = 0.0
    for o, m, l in parts:
        scale = jnp.exp(m - m_all)                # [B,H,1]
        tot_l = tot_l + l * scale
        tot_o = tot_o + o * jnp.moveaxis(scale, 1, -1)[..., None]
    tot_l = jnp.maximum(tot_l, 1e-30)
    return tot_o / jnp.moveaxis(tot_l, 1, -1)[..., None]


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------
def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    enc_mask: Optional[jax.Array] = None) -> jax.Array:
    """q: [B, Sq, H, D] over encoder memory k/v: [B, Se, KV, D]."""
    b, sq, h, d = q.shape
    _, se, kv, _ = k.shape
    k = _expand_kv(k, h // kv)
    v = _expand_kv(v, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= d ** -0.5
    if enc_mask is not None:
        scores = jnp.where(enc_mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
