"""Tiered embedding table — HADES applied to vocab rows.

Token frequency is zipfian (a few thousand rows absorb most lookups), so
the embedding table is the canonical hotness-fragmented object array: hot
rows scattered across a 100k-row table pin the whole table in HBM. The
tiered table keeps a dense HOT replica of the top rows in HBM and leaves
the full table in the host tier; a two-level remap (the object table of
this pool) routes lookups.

Functional state:
  full   [V, D]  — authoritative table ("host" tier on a real TPU:
                   memory_kind="pinned_host")
  hot    [Hn, D] — dense HBM replica of the currently-hot rows
  remap  [V]     — row -> hot index, or -1 (cold: read through to host)
  counts [V]     — EMA access counts (the access-bit analog)

`lookup` gathers hot rows from the replica and cold rows from the full
table (a cold hit is a promotion event — the MIAD signal). `collect`
re-elects the top-Hn rows and rebuilds the replica (the Object
Collector's migration, at row granularity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TieredEmbeddingConfig:
    vocab_size: int
    d_model: int
    hot_rows: int
    ema: float = 0.9


def init(cfg: TieredEmbeddingConfig, table: jax.Array) -> Dict:
    """Wrap an existing [V, D] table. Initial hot set: first hot_rows."""
    hot_ids = jnp.arange(cfg.hot_rows, dtype=jnp.int32)
    remap = jnp.full((cfg.vocab_size,), -1, jnp.int32) \
        .at[hot_ids].set(jnp.arange(cfg.hot_rows, dtype=jnp.int32))
    return {
        "full": table,
        "hot": table[hot_ids],
        "hot_ids": hot_ids,
        "remap": remap,
        "counts": jnp.zeros((cfg.vocab_size,), jnp.float32),
        "win_lookups": jnp.zeros((), jnp.int32),
        "win_cold_hits": jnp.zeros((), jnp.int32),
    }


def lookup(cfg: TieredEmbeddingConfig, state: Dict, tokens: jax.Array
           ) -> Tuple[jax.Array, Dict]:
    """tokens: [...] int32 -> (embeddings [..., D], state with counters).
    Hot rows come from the dense HBM replica; cold rows read through to
    the full (host-tier) table — each cold hit is a promotion event."""
    hot_idx = state["remap"][tokens]                   # [...], -1 = cold
    is_hot = hot_idx >= 0
    from_hot = state["hot"][jnp.maximum(hot_idx, 0)]
    from_full = state["full"][tokens]
    out = jnp.where(is_hot[..., None], from_hot, from_full)
    counts = state["counts"].at[tokens.reshape(-1)].add(1.0)
    return out, dict(
        state, counts=counts,
        win_lookups=state["win_lookups"] + tokens.size,
        win_cold_hits=state["win_cold_hits"] +
        jnp.sum(~is_hot).astype(jnp.int32))


def collect(cfg: TieredEmbeddingConfig, state: Dict) -> Tuple[Dict, Dict]:
    """Re-elect the hot set from EMA counts and rebuild the dense replica
    (row migration). Returns (state, report)."""
    counts = state["counts"]
    _, hot_ids = jax.lax.top_k(counts, cfg.hot_rows)
    hot_ids = hot_ids.astype(jnp.int32)
    remap = jnp.full((cfg.vocab_size,), -1, jnp.int32) \
        .at[hot_ids].set(jnp.arange(cfg.hot_rows, dtype=jnp.int32))
    cold_rate = state["win_cold_hits"].astype(jnp.float32) / \
        jnp.maximum(state["win_lookups"].astype(jnp.float32), 1.0)
    report = {"cold_hit_rate": cold_rate,
              "hot_coverage": jnp.sum(counts[hot_ids]) /
              jnp.maximum(jnp.sum(counts), 1.0)}
    new_state = dict(
        state, hot=state["full"][hot_ids], hot_ids=hot_ids, remap=remap,
        counts=counts * cfg.ema,
        win_lookups=jnp.zeros((), jnp.int32),
        win_cold_hits=jnp.zeros((), jnp.int32))
    return new_state, report


def write_rows(state: Dict, rows: jax.Array, values: jax.Array) -> Dict:
    """Training update path: write full table; refresh any hot replicas."""
    full = state["full"].at[rows].set(values)
    hot_idx = state["remap"][rows]
    is_hot = hot_idx >= 0
    n_hot = state["hot"].shape[0]
    hot = state["hot"].at[jnp.where(is_hot, hot_idx, n_hot)].set(
        values, mode="drop")
    return dict(state, full=full, hot=hot)


def hbm_bytes(cfg: TieredEmbeddingConfig, dtype=jnp.bfloat16) -> int:
    return cfg.hot_rows * cfg.d_model * jnp.dtype(dtype).itemsize


def total_bytes(cfg: TieredEmbeddingConfig, dtype=jnp.bfloat16) -> int:
    return cfg.vocab_size * cfg.d_model * jnp.dtype(dtype).itemsize
