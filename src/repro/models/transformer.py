"""Model composition: decoder-only LMs (dense / MoE / SWA), encoder-decoder
(seamless-m4t), hybrid SSM+shared-attention (zamba2), and pure SSM
(falcon-mamba). One init + forward + prefill + decode_step per family, all
driven by ModelConfig; layers run under lax.scan with stacked params and an
optional remat policy.

Decode state layout (pytree of stacked-per-layer arrays so decode also scans):
  attention layers: {"k": [L,B,C,KV,Dh], "v": [L,B,C,KV,Dh],
                     "k_pos": [L,B,C] (ring buffers for SWA), "pos": []}
  ssm layers:       {"h": [L,B,...], "conv": [L,B,K-1,C]}
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, MAMBA1, MAMBA2, SHARED_ATTN,
                                ModelConfig)
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

# ---------------------------------------------------------------------------
# Layer-scan control. Production runs keep lax.scan rolled (small HLO,
# fast compiles). The dry-run fully unrolls so compiled.cost_analysis()
# counts every layer (XLA's cost model counts a while-loop body ONCE —
# rolled-scan FLOPs/collectives would be ~L x undercounted).
# ---------------------------------------------------------------------------
_SCAN_UNROLL = False


def set_scan_unroll(on: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = on


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True if _SCAN_UNROLL else 1)


# ---------------------------------------------------------------------------
# Remat policies
# ---------------------------------------------------------------------------
REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def _maybe_remat(fn, remat: str):
    policy = REMAT_POLICIES[remat]
    if remat == "none":
        return fn
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


# ---------------------------------------------------------------------------
# Attention block (pre-norm attn + FFN/MoE), shared by all families
# ---------------------------------------------------------------------------
def init_attn_layer(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    p = {
        "ln1": L.init_rms_norm(d),
        "ln2": L.init_rms_norm(d),
        "wq": (jax.random.normal(ks[0], (d, nq)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq, d)) * nq ** -0.5).astype(dtype),
    }
    if cfg.num_experts:
        p["moe"] = moe_lib.init_moe(ks[4], cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(ks[4], d, cfg.d_ff, cfg.mlp_gated, dtype)
    if cross:
        p["ln_x"] = L.init_rms_norm(d)
        p["xq"] = (jax.random.normal(ks[5], (d, nq)) * s).astype(dtype)
        p["xk"] = (jax.random.normal(ks[6], (d, nkv)) * s).astype(dtype)
        p["xv"] = (jax.random.normal(ks[7], (d, nkv)) * s).astype(dtype)
        p["xo"] = (jax.random.normal(ks[8], (nq, d)) * nq ** -0.5).astype(dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    q = L.positional(cfg, q, positions)
    k = L.positional(cfg, k, positions)
    return q, k, v


def attn_ffn_block(p: dict, x: jax.Array, cfg: ModelConfig, positions,
                   *, causal: bool = True, attn_impl: str = "blockwise",
                   enc_kv=None, enc_mask=None):
    """Full-sequence block. Returns (x, aux_loss, kv, expert_counts)."""
    b, s, _ = x.shape
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions)
    kwargs = dict(causal=causal, window=cfg.sliding_window,
                  q_pos=_pos2d(positions, b, s), k_pos=_pos2d(positions, b, s))
    if attn_impl == "full":
        o = attn_lib.full_attention(q, k, v, **kwargs)
    elif attn_impl == "blockwise":
        o = attn_lib.blockwise_attention(q, k, v, chunk=min(512, s), **kwargs)
    elif attn_impl == "flash":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal,
                                 window=cfg.sliding_window)
    else:
        raise ValueError(attn_impl)
    o = o.reshape(b, s, -1)
    x = x + jnp.einsum("bse,ed->bsd", o, p["wo"])

    if enc_kv is not None:  # cross attention
        hx = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        qx = jnp.einsum("bsd,de->bse", hx, p["xq"]).reshape(
            b, s, cfg.num_heads, hd)
        ox = attn_lib.cross_attention(qx, enc_kv[0], enc_kv[1], enc_mask)
        x = x + jnp.einsum("bse,ed->bsd", ox.reshape(b, s, -1), p["xo"])

    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    counts = jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)
    if cfg.num_experts:
        f, aux, counts = moe_lib.moe_block(p["moe"], h2, cfg)
    else:
        f = L.mlp(p["ffn"], h2, cfg.mlp_gated)
    return x + f, aux, (k, v), counts


def _pos2d(positions, b, s):
    """Reduce mrope [3,B,S] to primary stream for masking."""
    if positions is None:
        return None
    return positions[0] if positions.ndim == 3 else positions


# ---------------------------------------------------------------------------
# Decode-mode attention block
# ---------------------------------------------------------------------------
def decode_layer_step(p: dict, x: jax.Array, cfg: ModelConfig, positions,
                      attend_fn, enc_kv=None):
    """One decoder layer of single-token decode — THE single place the
    layer math lives, with the KV mechanics supplied by the caller:
    `attn_block_decode` plugs in the dense ring cache, the paged server
    (runtime/server.py) plugs in HadesPool append+attend. `_qkv` runs
    exactly once per layer (the old server derived it twice, and its
    two-phase k/v loop computed deep layers' k/v from the embedding —
    the decode corruption this hoist removes).

    x: [B,1,D]; positions: [B,1] (per-sequence positions, pre-broadcast);
    attend_fn(q, k, v) -> (attn out reshapeable to [B,1,H*Dh], aux) with
    q [B,1,H,Dh], k/v [B,1,KV,Dh]; `aux` is whatever cache/pool state the
    caller must thread onward. Returns (x', aux, expert_counts)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions)
    o, aux = attend_fn(q, k, v)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(b, 1, -1), p["wo"])

    if enc_kv is not None:
        hx = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,de->bse", hx, p["xq"]).reshape(
            b, 1, cfg.num_heads, hd)
        ox = attn_lib.cross_attention(qx, enc_kv[0], enc_kv[1])
        x = x + jnp.einsum("bse,ed->bsd", ox.reshape(b, 1, -1), p["xo"])

    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    counts = jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)
    if cfg.num_experts:
        t = h2.shape[0] * h2.shape[1]
        if cfg.hades.expert_gather_decode and \
                t * cfg.experts_per_token < cfg.num_experts:
            # HADES hot-expert principle on the weight stream: fetch only
            # the routed experts (exact; wins when T*k < E)
            f, _, counts = moe_lib.moe_block_gathered(p["moe"], h2, cfg)
        else:
            f, _, counts = moe_lib.moe_block(p["moe"], h2, cfg)
    else:
        f = L.mlp(p["ffn"], h2, cfg.mlp_gated)
    return x + f, aux, counts


def attn_block_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict,
                      pos, enc_kv=None):
    """x: [B,1,D]; cache: {"k","v": [B,C,KV,Dh], "k_pos": [B,C]}. Appends the
    new token at slot pos % C (ring for SWA, linear otherwise) and attends.
    Returns (x, new_cache, counts)."""
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (b, 1))
    c = cache["k"].shape[1]

    def attend(q, k, v):
        slot = pos % c
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        k_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pos"],
            jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (b, 1)),
            slot, axis=1)
        cache_len = jnp.minimum(pos + 1, c)
        o = attn_lib.decode_attention(q, k_cache, v_cache, cache_len,
                                      window=cfg.sliding_window,
                                      k_pos=k_pos, q_pos=pos)
        return o, {"k": k_cache, "v": v_cache, "k_pos": k_pos}

    return decode_layer_step(p, x, cfg, positions, attend, enc_kv=enc_kv)


# ---------------------------------------------------------------------------
# Family: decoder-only LM (dense, MoE, VLM backbone)
# ---------------------------------------------------------------------------
def init_lm(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    n_attn = sum(1 for k in cfg.blocks if k == ATTN)
    params = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_ln": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["out"] = L.init_embedding(
            keys[1], cfg.vocab_size, cfg.d_model, dtype).T
    if cfg.family in ("ssm",):
        def mk(k):
            return {"ln": L.init_rms_norm(cfg.d_model),
                    "m": ssm_lib.init_mamba1(k, cfg, dtype)}
        params["layers"] = jax.vmap(mk)(jax.random.split(keys[2], cfg.num_layers))
    elif cfg.family == "hybrid":
        per, groups = _hybrid_shape(cfg)

        def mk(k):
            return {"ln": L.init_rms_norm(cfg.d_model),
                    "m": ssm_lib.init_mamba2(k, cfg, dtype)}
        ks2 = jax.random.split(keys[2], groups * per)
        ks2 = ks2.reshape((groups, per) + ks2.shape[1:])
        params["mamba"] = jax.vmap(jax.vmap(mk))(ks2)
        params["shared_attn"] = init_attn_layer(keys[3], cfg, dtype)
    else:
        params["layers"] = jax.vmap(
            lambda k: init_attn_layer(k, cfg, dtype))(
                jax.random.split(keys[2], cfg.num_layers))
    if cfg.is_encoder_decoder:
        params["enc_layers"] = jax.vmap(
            lambda k: init_attn_layer(k, cfg, dtype))(
                jax.random.split(keys[4], cfg.num_encoder_layers))
        params["enc_ln"] = L.init_rms_norm(cfg.d_model)
        # decoder layers get cross-attention
        params["layers"] = jax.vmap(
            lambda k: init_attn_layer(k, cfg, dtype, cross=True))(
                jax.random.split(keys[2], cfg.num_layers))
    return params


def _hybrid_shape(cfg: ModelConfig) -> Tuple[int, int]:
    """(mamba blocks per group, groups) for the hybrid pattern."""
    every = cfg.shared_attn_every
    assert cfg.num_layers % every == 0
    return every - 1, cfg.num_layers // every


def lm_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
               positions: Optional[jax.Array] = None,
               extra_embeds: Optional[jax.Array] = None,
               enc_embeds: Optional[jax.Array] = None,
               attn_impl: str = "blockwise", remat: str = "none",
               return_cache: bool = False, return_hiddens: bool = False):
    """tokens: [B, S_txt]. extra_embeds (VLM patches): [B, P, D] prepended.
    enc_embeds (enc-dec audio frames): [B, S_enc, D].
    Returns logits [B, S, V] (+ aux dict). `return_hiddens` (attn-family
    layers only) adds aux["hiddens"] [L, B, S, D] — the post-layer
    residual stream, for per-layer decode/prefill divergence reports."""
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    enc_out = None
    if cfg.is_encoder_decoder:
        assert enc_embeds is not None
        enc_out = encoder_forward(params, cfg, enc_embeds,
                                  attn_impl=attn_impl, remat=remat)

    aux_total = jnp.zeros((), jnp.float32)
    counts_total = jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)
    cache = None
    counts_per_layer = None
    hs = None

    if cfg.family == "ssm":
        def body(h, lp):
            y, _ = ssm_lib.mamba1_forward(
                lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg)
            return h + y, None
        body = _maybe_remat(body, remat)
        x, _ = _scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        x, aux_total, counts_total = _hybrid_forward(
            params, cfg, x, positions, attn_impl, remat)
    else:
        kv_all = [] if return_cache else None

        def body(carry, lp):
            h = carry
            h, aux, kv, cnt = attn_ffn_block(
                lp, h, cfg, positions, attn_impl=attn_impl,
                enc_kv=_enc_kv(lp, enc_out, cfg) if enc_out is not None else None)
            return h, (aux, cnt, kv if return_cache else None,
                       h if return_hiddens else None)
        body = _maybe_remat(body, remat)
        x, (auxs, cnts, kvs, hs) = _scan(body, x, params["layers"])
        aux_total = jnp.sum(auxs)
        counts_total = jnp.sum(cnts, axis=0)
        counts_per_layer = cnts
        if return_cache:
            cache = kvs

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    out_t = params["embed"].T if cfg.tie_embeddings else params["out"]
    logits = L.logits_head(out_t, x)
    aux = {"moe_aux_loss": aux_total, "expert_counts": counts_total}
    if counts_per_layer is not None:
        aux["expert_counts_per_layer"] = counts_per_layer
    if return_cache:
        aux["kv_cache"] = cache
        aux["enc_out"] = enc_out
    if return_hiddens:
        assert hs is not None, "return_hiddens: attn-family layers only"
        aux["hiddens"] = hs
    return logits, aux


def _enc_kv(lp, enc_out, cfg: ModelConfig):
    """Project encoder memory to this decoder layer's cross K/V."""
    b, se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, lp["xk"]).reshape(
        b, se, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, lp["xv"]).reshape(
        b, se, cfg.num_kv_heads, hd)
    return (k, v)


def encoder_forward(params, cfg: ModelConfig, enc_embeds, *,
                    attn_impl="blockwise", remat="none"):
    b, s, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))

    def body(h, lp):
        h, _, _, _ = attn_ffn_block(lp, h, cfg, positions, causal=False,
                                    attn_impl=attn_impl)
        return h, None
    body = _maybe_remat(body, remat)
    x, _ = _scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _hybrid_forward(params, cfg: ModelConfig, x, positions, attn_impl, remat):
    """zamba2: groups of (every-1) mamba2 blocks + one SHARED attn block."""
    shared = params["shared_attn"]
    b = x.shape[0]

    def group_body(carry, group_params):
        h = carry

        def mamba_body(hh, lp):
            y, _ = ssm_lib.mamba2_forward(
                lp["m"], L.rms_norm(hh, lp["ln"], cfg.norm_eps), cfg)
            return hh + y, None
        h, _ = _scan(mamba_body, h, group_params)
        h, aux, _, cnt = attn_ffn_block(shared, h, cfg, positions,
                                        attn_impl=attn_impl)
        return h, (aux, cnt)
    group_body = _maybe_remat(group_body, remat)
    x, (auxs, cnts) = _scan(group_body, x, params["mamba"])
    return x, jnp.sum(auxs), jnp.sum(cnts, axis=0)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(params, cfg: ModelConfig, tokens, labels, *,
            extra_embeds=None, enc_embeds=None,
            attn_impl="blockwise", remat="none"):
    """Next-token cross entropy; labels == -100 are masked."""
    logits, aux = lm_forward(params, cfg, tokens, extra_embeds=extra_embeds,
                             enc_embeds=enc_embeds, attn_impl=attn_impl,
                             remat=remat)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    mask = labels != -100
    labels_safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + 0.01 * aux["moe_aux_loss"], aux


# ---------------------------------------------------------------------------
# Decode: state init + step
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_out: Optional[jax.Array] = None) -> dict:
    """Dense (non-paged) decode state. max_len is clipped to the SWA window
    for windowed archs (ring buffer)."""
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    c = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    state: Dict = {"pos": jnp.zeros((), jnp.int32)}

    def kv(n_layers):
        return {
            "k": jnp.zeros((n_layers, batch, c, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_layers, batch, c, cfg.num_kv_heads, hd), dtype),
            "k_pos": jnp.full((n_layers, batch, c), -1, jnp.int32),
        }
    if cfg.family == "ssm":
        state["ssm"] = jax.vmap(
            lambda _: ssm_lib.mamba1_init_state(cfg, batch, dtype))(
                jnp.arange(cfg.num_layers))
    elif cfg.family == "hybrid":
        per, groups = _hybrid_shape(cfg)
        state["ssm"] = jax.vmap(jax.vmap(
            lambda _: ssm_lib.mamba2_init_state(cfg, batch, dtype)))(
                jnp.arange(groups * per).reshape(groups, per))
        state["kv"] = kv(groups)  # one cache per shared-attn occurrence
    else:
        state["kv"] = kv(cfg.num_layers)
    if cfg.is_encoder_decoder:
        assert enc_out is not None
        state["enc_out"] = enc_out
    return state


def lm_decode_step(params: dict, cfg: ModelConfig, state: dict,
                   tokens: jax.Array, *, return_hiddens: bool = False):
    """tokens: [B] -> (logits [B, V], new state). One token per sequence.
    `return_hiddens` (attn family only) appends a third output: the
    post-layer residual stream [L, B, 1, D] for divergence reports."""
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens)[:, None, :]  # [B,1,D]
    pos = state["pos"]
    counts_total = jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)

    if cfg.family == "ssm":
        def body(h, xs):
            lp, st = xs
            y, st2 = ssm_lib.mamba1_step(
                lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg, st)
            return h + y, st2
        x, new_ssm = _scan(body, x, (params["layers"], state["ssm"]))
        state = dict(state, ssm=new_ssm, pos=pos + 1)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, xs):
            gp, sst, kvc = xs

            def mamba_body(hh, ys):
                lp, st = ys
                y, st2 = ssm_lib.mamba2_step(
                    lp["m"], L.rms_norm(hh, lp["ln"], cfg.norm_eps), cfg, st)
                return hh + y, st2
            h, new_sst = _scan(mamba_body, h, (gp, sst))
            h, new_kv, cnt = attn_block_decode(shared, h, cfg, kvc, pos)
            return h, (new_sst, new_kv, cnt)
        x, (new_ssm, new_kv, cnts) = _scan(
            group_body, x, (params["mamba"], state["ssm"], state["kv"]))
        counts_total = jnp.sum(cnts, axis=0)
        state = dict(state, ssm=new_ssm, kv=new_kv, pos=pos + 1)
    else:
        enc_out = state.get("enc_out")

        def body(h, xs):
            lp, kvc = xs
            h, new_kv, cnt = attn_block_decode(
                lp, h, cfg, kvc, pos,
                enc_kv=_enc_kv(lp, enc_out, cfg) if enc_out is not None else None)
            return h, (new_kv, cnt, h if return_hiddens else None)
        x, (new_kv, cnts, hs) = _scan(body, x, (params["layers"],
                                                state["kv"]))
        counts_total = jnp.sum(cnts, axis=0)
        state = dict(state, kv=new_kv, pos=pos + 1)

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    out_t = params["embed"].T if cfg.tie_embeddings else params["out"]
    logits = L.logits_head(out_t, x)[:, 0]
    if return_hiddens:
        assert cfg.family not in ("ssm", "hybrid"), \
            "return_hiddens: attn-family layers only"
        return logits, state, hs
    return logits, state
