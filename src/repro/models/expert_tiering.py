"""MoE expert tiering — HADES management plane for expert slabs.

Per-expert routed-token counts (returned by moe_block every step) are the
access bitmap at expert granularity. This module runs the same
CIW + MIAD state machine over experts: hot experts stay HBM-resident
("huge-page promoted": their slabs kept dense/contiguous), cold experts
become demotion candidates and are paged to host once the re-route rate
(promotions) is safely below target.

This is the *management plane*: residency decisions + accounting. On a
real TPU the data plane moves the slab with device_put to
memory_kind="pinned_host" and streams it back on a fault; on CPU (this
container) residency is tracked and fault penalties are counted, which is
what the benchmarks measure. olmoe (64 experts, top-8) is the headroom
case: steady-state routing concentrates, and the cold majority of slabs
can leave HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ExpertTieringConfig:
    num_layers: int
    num_experts: int
    bytes_per_expert: int
    ciw_threshold: int = 3
    ciw_max: int = 31
    promotion_target: float = 0.01
    miad_mult: float = 2.0
    miad_add: float = 1.0
    ct_min: float = 1.0
    ct_max: float = 16.0


def init(cfg: ExpertTieringConfig) -> Dict:
    le = (cfg.num_layers, cfg.num_experts)
    return {
        "ciw": jnp.zeros(le, jnp.int32),
        "resident": jnp.ones(le, jnp.bool_),     # HBM-resident slabs
        "ct": jnp.asarray(float(cfg.ciw_threshold), jnp.float32),
        "win_routed": jnp.zeros((), jnp.int32),
        "win_promos": jnp.zeros((), jnp.int32),  # tokens routed to demoted
        "total_faults": jnp.zeros((), jnp.int32),
    }


def observe(cfg: ExpertTieringConfig, state: Dict, counts: jax.Array
            ) -> Dict:
    """counts: [L, E] tokens routed per expert this step. Tokens hitting a
    non-resident expert are promotion events (the slab faults back)."""
    hit = counts > 0
    faulted = hit & ~state["resident"]
    return dict(
        state,
        resident=state["resident"] | faulted,     # fault-in
        win_routed=state["win_routed"] + jnp.sum(counts),
        win_promos=state["win_promos"] +
        jnp.sum(jnp.where(faulted, counts, 0)),
        total_faults=state["total_faults"] +
        jnp.sum(faulted).astype(jnp.int32),
        # stash hits for collect (access bits)
        _hits=hit)


def collect(cfg: ExpertTieringConfig, state: Dict) -> Tuple[Dict, Dict]:
    """CIW update + MIAD + demotion of cold expert slabs."""
    hits = state.get("_hits", jnp.zeros_like(state["ciw"], jnp.bool_))
    ciw = jnp.where(hits, 0, jnp.minimum(state["ciw"] + 1, cfg.ciw_max))
    rate = state["win_promos"].astype(jnp.float32) / \
        jnp.maximum(state["win_routed"].astype(jnp.float32), 1.0)
    hot = rate > cfg.promotion_target
    ct = jnp.where(hot,
                   jnp.minimum(state["ct"] * cfg.miad_mult, cfg.ct_max),
                   jnp.maximum(state["ct"] - cfg.miad_add, cfg.ct_min))
    demote = ciw > jnp.floor(ct).astype(jnp.int32)
    resident = state["resident"] & ~demote
    n_resident = jnp.sum(resident)
    report = {
        "promotion_rate": rate,
        "resident_experts": n_resident,
        "hbm_bytes": n_resident.astype(jnp.float32) * cfg.bytes_per_expert,
        "total_bytes": float(cfg.num_layers * cfg.num_experts *
                             cfg.bytes_per_expert),
        "ct": ct,
    }
    new_state = dict(state, ciw=ciw, resident=resident, ct=ct,
                     win_routed=jnp.zeros((), jnp.int32),
                     win_promos=jnp.zeros((), jnp.int32))
    new_state.pop("_hits", None)
    return new_state, report
