"""HADES frontend orchestration — the public API of the paper's system.

`Hades` wires the pieces together exactly as Figure 4 draws them:

    application --alloc/read/write--> HadesPool (object table + heaps)
                                         |
                          every N steps: arm -> collect (Object Collector,
                                         MIAD, MADV_COLD candidates)
                                         |
                        superblock stats (page-level view only) + bstate
                                         v
                         backend.make(name).step — any registered backend
                         (reactive / proactive / cap / null / mglru /
                         promote, see backend.names()), unmodified and
                         object-oblivious; stateful backends carry their
                         own state (`bstate`) across windows inside the
                         scan carry (docs/backends.md)

Since the fused-window refactor this class is a thin compatibility shim
over `core/engine.py`: every op is ONE compiled dispatch (the collect +
backend pass is fused into the op that closes a window — the host only
keeps the deterministic op clock), and batched callers should skip the
shim entirely and drive `Engine.run_window` / `serve_steps`, which run
`collect_every` steps per dispatch. Both paths execute identical
transitions (tests/test_engine.py asserts bit-parity).

Every engine entry point DONATES the pool state it is handed (in-place
window updates — docs/allocator.md): this class is the reference for
the caller contract, reassigning `self.state` from each call's result
and never touching the previous pytree again. External holders of
`h.state` must re-read it after any op; a stale reference raises a
deleted-buffer error rather than silently aliasing old bytes.

Note: `free` advances the window clock like every other op (the engine's
scan needs a data-independent clock); the pre-engine frontend did not
tick on free.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import object_table as ot
from repro.core import page_util
from repro.core import pool as pl

# back-compat alias: same fields, same defaults, now hashable engine config
HadesOptions = eng.EngineOptions


class Hades:
    """One managed pool + its collector/backend loop."""

    def __init__(self, pool_cfg: pl.PoolConfig,
                 opts: Optional[HadesOptions] = None):
        self.cfg = pool_cfg
        self.opts = opts or HadesOptions()
        self.engine = eng.Engine(pool_cfg, self.opts)
        self.state = self.engine.init()
        self._step = 0
        self.last_report: Dict[str, jax.Array] = {}

    # -- window clock (host mirror of the device-side cadence) ---------------
    def _flags(self):
        if not self.opts.enabled:
            return False, False
        nxt = self._step + 1
        every = self.opts.collect_every
        do_arm = self.opts.overlap_collect and nxt % every == every - 1
        do_collect = nxt % every == 0
        return do_arm, do_collect

    def _op(self, op: str, obj_ids, values=None):
        do_arm, do_collect = self._flags()
        self.state, out, report = self.engine.step(
            self.state, op, obj_ids, values, do_arm=do_arm,
            do_collect=do_collect)
        self._step += 1
        if do_collect:
            self.last_report = report
        return out

    # -- application-facing ops ---------------------------------------------
    def alloc(self, obj_ids, values):
        self._op("alloc", obj_ids, values)

    def read(self, obj_ids) -> jax.Array:
        return self._op("read", obj_ids)

    def write(self, obj_ids, values):
        self._op("write", obj_ids, values)

    def free(self, obj_ids):
        self._op("free", obj_ids)

    def end_load_phase(self):
        """Clear load-time access bits + window counters without
        classifying — the run starts with a fresh observation window
        (allocation stores are not workload accesses)."""
        self.state = dict(
            self.state,
            table=ot.clear_access_and_atc(self.state["table"]),
            slot_ref=jnp.zeros_like(self.state["slot_ref"]),
            win_accesses=jnp.zeros((), jnp.int32),
            win_promos=jnp.zeros((), jnp.int32),
            win_faults=jnp.zeros((), jnp.int32))
        self._step = 0

    # -- collector/backend loop ----------------------------------------------
    def collect(self):
        """Force a collect+backend pass now (one dispatch)."""
        self.state, self.last_report = self.engine.collect_now(self.state)

    # -- metrics ---------------------------------------------------------------
    def rss_bytes(self) -> int:
        return int(pl.rss_bytes(self.cfg, self.state))

    def host_bytes(self) -> int:
        return int(pl.host_bytes(self.cfg, self.state))

    def page_utilization(self) -> float:
        return float(page_util.from_pool(self.cfg, self.state))

    def heap_histogram(self) -> Dict[str, int]:
        tbl = self.state["table"]
        h = ot.heap_of(tbl)
        live = ot.is_live(tbl)
        return {name: int(jnp.sum(live & (h == hid)))
                for name, hid in (("new", ot.NEW), ("hot", ot.HOT),
                                  ("cold", ot.COLD))}

    def counters(self) -> Dict[str, int]:
        s = self.state
        return {"faults": int(s["total_faults"]),
                "moves": int(s["total_moves"]),
                "epoch": int(s["epoch"]),
                "ciw_threshold": float(s["ciw_threshold"])}
