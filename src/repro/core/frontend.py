"""HADES frontend orchestration — the public API of the paper's system.

`Hades` wires the pieces together exactly as Figure 4 draws them:

    application --alloc/read/write--> HadesPool (object table + heaps)
                                         |
                          every N steps: arm -> collect (Object Collector,
                                         MIAD, MADV_COLD candidates)
                                         |
                             superblock stats (page-level view only)
                                         v
                                    backend.step (reactive / proactive /
                                    cap / null — unmodified, oblivious)

The class is a thin stateful convenience wrapper: all state lives in a
pytree (`self.state`) and every transition is a jitted pure function, so
the same machinery runs inside pjit'd serving steps (see models/kvcache).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.core import collector as col
from repro.core import object_table as ot
from repro.core import page_util
from repro.core import policy
from repro.core import pool as pl


@dataclasses.dataclass(frozen=True)
class HadesOptions:
    collect_every: int = 8
    backend: be.BackendConfig = dataclasses.field(
        default_factory=be.BackendConfig)
    collector: col.CollectorConfig = dataclasses.field(
        default_factory=col.CollectorConfig)
    enabled: bool = True           # False = allocator-only (no tidying)
    # Arm ATC tracking for the window preceding each collect. The paper's
    # scope guards decrement on function EXIT; in a synchronous loop every
    # step has exited before the collector runs, so nothing is in flight
    # and arming would only veto migrations spuriously. Set True when the
    # runtime overlaps step dispatch with collection (async serving) —
    # then ATC>0 marks objects a concurrent step may still dereference.
    overlap_collect: bool = False


class Hades:
    """One managed pool + its collector/backend loop."""

    def __init__(self, pool_cfg: pl.PoolConfig,
                 opts: Optional[HadesOptions] = None):
        self.cfg = pool_cfg
        self.opts = opts or HadesOptions()
        self.state = pl.init(pool_cfg)
        self._step = 0
        self.last_report: Dict[str, jax.Array] = {}
        # jitted transitions (static config closed over)
        self._alloc = jax.jit(functools.partial(pl.alloc, pool_cfg))
        self._read = jax.jit(functools.partial(pl.read, pool_cfg))
        self._write = jax.jit(functools.partial(pl.write, pool_cfg))
        self._free = jax.jit(functools.partial(pl.free, pool_cfg))
        self._collect = jax.jit(functools.partial(
            col.collect, pool_cfg, self.opts.collector))
        self._backend = jax.jit(functools.partial(
            be.step, self.opts.backend, pool_cfg))

    # -- application-facing ops ---------------------------------------------
    def alloc(self, obj_ids, values):
        self.state = self._alloc(self.state, jnp.asarray(obj_ids, jnp.int32),
                                 values)
        self._tick()

    def read(self, obj_ids) -> jax.Array:
        vals, self.state = self._read(self.state,
                                      jnp.asarray(obj_ids, jnp.int32))
        self._tick()
        return vals

    def write(self, obj_ids, values):
        self.state = self._write(self.state, jnp.asarray(obj_ids, jnp.int32),
                                 values)
        self._tick()

    def free(self, obj_ids):
        self.state = self._free(self.state, jnp.asarray(obj_ids, jnp.int32))

    def end_load_phase(self):
        """Clear load-time access bits + window counters without
        classifying — the run starts with a fresh observation window
        (allocation stores are not workload accesses)."""
        self.state = dict(
            self.state,
            table=ot.clear_access_and_atc(self.state["table"]),
            win_accesses=jnp.zeros((), jnp.int32),
            win_promos=jnp.zeros((), jnp.int32),
            win_faults=jnp.zeros((), jnp.int32))
        self._step = 0

    # -- collector/backend loop ----------------------------------------------
    def _tick(self):
        self._step += 1
        if not self.opts.enabled:
            return
        every = self.opts.collect_every
        # epoch protocol: ATC instrumentation is live only during the
        # armed step, and only when collection overlaps execution
        if self.opts.overlap_collect and self._step % every == every - 1:
            self.state = col.arm(self.state)
        elif self._step % every == 0:
            self.collect()

    def collect(self):
        self.state, report = self._collect(self.state)
        # backend sees the closing window's superblock stats (pre-clear)
        stats = report.pop("sb_stats")
        tier, evict = self._backend(stats, self.state["sb_tier"],
                                    self.state["sb_evict"],
                                    report["proactive_ok"])
        self.state = dict(self.state, sb_tier=tier, sb_evict=evict)
        self.last_report = report

    # -- metrics ---------------------------------------------------------------
    def rss_bytes(self) -> int:
        return int(pl.rss_bytes(self.cfg, self.state))

    def host_bytes(self) -> int:
        return int(pl.host_bytes(self.cfg, self.state))

    def page_utilization(self) -> float:
        return float(page_util.from_pool(self.cfg, self.state))

    def heap_histogram(self) -> Dict[str, int]:
        tbl = self.state["table"]
        h = ot.heap_of(tbl)
        live = ot.is_live(tbl)
        return {name: int(jnp.sum(live & (h == hid)))
                for name, hid in (("new", ot.NEW), ("hot", ot.HOT),
                                  ("cold", ot.COLD))}

    def counters(self) -> Dict[str, int]:
        s = self.state
        return {"faults": int(s["total_faults"]),
                "moves": int(s["total_moves"]),
                "epoch": int(s["epoch"]),
                "ciw_threshold": float(s["ciw_threshold"])}
