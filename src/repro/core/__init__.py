"""HADES core — the paper's contribution as a composable JAX module.

  object_table  tagged-pointer analog: packed per-object metadata words
  pool          fixed-size-object heap (NEW/HOT/COLD regions, superblocks,
                HBM/host tiers, fault accounting) — jit/pjit native
  collector     Object Collector: scan, CIW, lock-free migration, compaction
  policy        MIAD feedback on the promotion rate
  backend       pluggable page-level reclamation backends — a registry of
                stateful Backend implementations (reactive/proactive/cap/
                null/mglru/promote), built via backend.make(name)
  page_util     the Page Utilization metric
  engine        fused window execution: the whole access->collect->backend
                loop as one jitted lax.scan (one dispatch per window)
  frontend      Hades: thin per-op compatibility wrapper over the engine
  simheap       byte-granular address-space simulator for the paper's
                YCSB/CrestDB evaluation (numpy, trace-driven)
"""
from repro.core import object_table  # noqa: F401
from repro.core.engine import Engine, EngineOptions  # noqa: F401
from repro.core.frontend import Hades, HadesOptions  # noqa: F401
from repro.core.pool import PoolConfig, make_config  # noqa: F401
