"""Object Collector — periodic scan + lock-free migration (paper §4).

Each collect pass, run between application steps (the migration window):

  1. Scan every table word: read access bits; update per-object CIW
     (Consecutive Inactive Windows).
  2. Classify (Fig. 5 state machine):
        accessed & heap in {NEW, COLD}         -> migrate to HOT
        ~accessed & CIW > C_t & heap in {NEW,HOT} -> migrate to COLD
  3. Migrate: an object moves ONLY if its ATC is zero (the paper's
     optimistic lock-free rule — an object observed in active use during
     the armed window is skipped and retried next pass; forward progress
     is never blocked).
  4. Destination slots are taken densely from the start of the target
     region, so HOT stays compact (huge-page-promotable) and COLD
     superblocks become uniformly cold.
  5. MIAD updates C_t from the window's promotion rate; access bits and
     ATCs are cleared; the epoch advances.

Everything is a fixed-shape array program: "no objects to move" is the
all-false mask, so the pass jits once and runs every window.

Execution shape: classification is one table sweep (`classify`, optionally
the Pallas `access_scan` kernel when `CollectorConfig.use_pallas`), and the
two-direction migration is one fused plan — destination slots for HOT and
COLD movers are computed back-to-back on the slot-owner array, then ALL
payload copies execute as a single data movement (the Pallas `migrate`
kernel, or one functional scatter on the jnp oracle path). Hot moves are
ordered before cold moves, which keeps the kernel's sequential-grid
contract: a cold mover may land in a slot a hot mover vacated, but no move
reads a slot an earlier move overwrote.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import freelist as fl
from repro.core import object_table as ot
from repro.core import policy
from repro.core import pool as pl


@dataclasses.dataclass(frozen=True)
class CollectorConfig:
    miad: policy.MiadConfig = dataclasses.field(default_factory=policy.MiadConfig)
    # keep NEW objects in NEW until they show a verdict (paper: NEW heap
    # absorbs fresh allocations; they migrate on first classification)
    promote_new_on_access: bool = True
    # route the table sweep + payload copies through the Pallas kernels
    # (access_scan / migrate); False keeps the pure-jnp oracle path. Both
    # paths are bit-identical (tests/test_engine.py asserts it).
    use_pallas: bool = False
    # max migrations per direction per collect (kswapd-style scan
    # budget): bounds the payload move and ALL per-mover metadata
    # updates to a pool-size-independent constant — movers beyond the
    # budget keep their masks' eligibility and retry next window (the
    # same deferral as a full destination region). 0 = unbounded.
    move_budget: int = 256


def classify(pool_cfg: pl.PoolConfig, col_cfg: CollectorConfig,
             state: Dict) -> Tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array]:
    """One sweep over the table: update CIW lanes, emit migration masks
    (Fig. 5 state machine, ATC lock-free rule folded in) and count the
    ATC-vetoed objects. Returns (table_with_new_ciw, to_hot, to_cold,
    skipped_atc) — the full classification comes from ONE table sweep on
    both paths (the Pallas kernel emits skipped_atc itself, so no table
    field is re-read in jnp)."""
    tbl = state["table"]
    if col_cfg.use_pallas:
        from repro.kernels import ops as kops
        # with_hist=False: the carried slot_ref bits already hold the
        # per-slot referenced view (and migrate moves them with the
        # objects), so the kernel's pre-move histogram would be dead work
        new_tbl, to_hot, to_cold, _, skipped = kops.access_scan(
            tbl, state["ciw_threshold"], sb_slots=pool_cfg.sb_slots,
            n_sbs=pool_cfg.n_sbs, with_hist=False)
        if not col_cfg.promote_new_on_access:
            # kernel bakes in NEW-promotes-on-access; mask it back out
            to_hot &= ot.heap_of(tbl) != ot.NEW
        return new_tbl, to_hot, to_cold, skipped

    live = ot.is_live(tbl)
    acc = (ot.access_of(tbl) == 1) & live
    atc = ot.atc_of(tbl)
    heap = ot.heap_of(tbl)
    ct = jnp.floor(state["ciw_threshold"]).astype(jnp.uint32)

    # --- CIW update (accessed -> 0; idle -> +1, saturating) ---
    ciw = ot.ciw_of(tbl)
    ciw = jnp.where(acc, 0, jnp.minimum(ciw + 1, ot.CIW_SAT))
    ciw = jnp.where(live, ciw, 0)

    # --- classification (Fig. 5) ---
    to_hot = acc & ((heap == ot.COLD) |
                    ((heap == ot.NEW) & col_cfg.promote_new_on_access))
    to_cold = (~acc) & (ciw > ct) & ((heap == ot.NEW) | (heap == ot.HOT))
    movable = live & (atc == 0)          # the lock-free rule
    to_hot &= movable
    to_cold &= movable
    skipped = jnp.sum(live & (atc > 0) &
                      (acc | ((ciw > ct) & (heap != ot.COLD)))
                      ).astype(jnp.int32)

    new_tbl = (tbl & ~(ot.CIW_MASK << ot.CIW_SHIFT)) | \
        (ciw.astype(jnp.uint32) << ot.CIW_SHIFT)
    return new_tbl, to_hot, to_cold, skipped


def _select_movers(to_hot: jax.Array, to_cold: jax.Array, m: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compress the two boolean mover masks [n] into fixed-size object-id
    lists [m] (ascending id, first-m-win — the budget's deferral order)
    with ONE sort over the table: hot movers key as their id, cold movers
    as id+n, everything else sorts past both. Returns
    (ids_hot, ok_hot, ids_cold, ok_cold). O(n log n) elementwise+sort —
    no O(n)-update scatter (the CPU-cost pig) anywhere."""
    n = to_hot.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(to_hot, idx, jnp.where(to_cold, idx + n, idx + 2 * n))
    skey = jnp.sort(key)
    n_hot = jnp.sum(to_hot.astype(jnp.int32))
    n_cold = jnp.sum(to_cold.astype(jnp.int32))
    j = jnp.arange(m, dtype=jnp.int32)
    ok_h = j < n_hot
    ids_h = jnp.where(ok_h, skey[jnp.minimum(j, n - 1)], 0)
    ok_c = j < n_cold
    ids_c = jnp.where(ok_c, skey[jnp.clip(n_hot + j, 0, n - 1)] - n, 0)
    return ids_h, ok_h, ids_c, ok_c


def _plan_moves(cfg: pl.PoolConfig, state: Dict, ids_m: jax.Array,
                ok_m: jax.Array, dest_heap: int
                ) -> Tuple[Dict, jax.Array, jax.Array, jax.Array]:
    """Assign destination slots in `dest_heap`'s region to the budgeted
    mover list `ids_m[ok_m]` (movers that find the region full are
    dropped — retried next window). Destinations POP off the region's
    free ring (dense-first as of the last restock) and vacated sources
    PUSH onto their regions' rings, so a later plan can claim slots this
    one vacates — all O(m). Updates metadata only; the payload copy is
    deferred to the fused data mover. Returns (state, src, dst, ok)."""
    tbl = state["table"]
    src = ot.slot_of(tbl[ids_m]).astype(jnp.int32)
    dst, ok_pop, head, count = fl.pop_region(
        cfg, state["free_q"], state["free_head"], state["free_count"],
        dest_heap, ok_m)
    ok = ok_m & ok_pop
    dst = jnp.where(ok, dst, src)

    # slot ownership: clear src, claim dst
    owner = state["slot_owner"] \
        .at[jnp.where(ok, src, cfg.n_slots)].set(-1, mode="drop") \
        .at[jnp.where(ok, dst, cfg.n_slots)].set(ids_m, mode="drop")
    # table word: new slot + heap (flags preserved; cleared later in pass)
    new_words = ot.with_heap(ot.with_slot(tbl[ids_m], dst.astype(jnp.uint32)),
                             dest_heap)
    tbl = tbl.at[jnp.where(ok, ids_m, cfg.max_objects)].set(
        new_words, mode="drop")
    # vacated sources back on their rings; occupancy + referenced bits
    # travel with the objects
    free_q, head, count = fl.push(cfg, state["free_q"], head, count,
                                  src, ok)
    sb_occ = state["sb_occ"] \
        .at[jnp.where(ok, src // cfg.sb_slots, cfg.n_sbs)].add(
            -1, mode="drop") \
        .at[jnp.where(ok, dst // cfg.sb_slots, cfg.n_sbs)].add(
            1, mode="drop")
    ref_src = state["slot_ref"][jnp.clip(src, 0, cfg.n_slots - 1)]
    slot_ref = state["slot_ref"] \
        .at[jnp.where(ok, src, cfg.n_slots)].set(False, mode="drop") \
        .at[jnp.where(ok, dst, cfg.n_slots)].set(ref_src, mode="drop")
    state = dict(state, table=tbl, slot_owner=owner, free_q=free_q,
                 free_head=head, free_count=count, sb_occ=sb_occ,
                 slot_ref=slot_ref)
    return state, src, dst, ok


def migrate(cfg: pl.PoolConfig, state: Dict, to_hot: jax.Array,
            to_cold: jax.Array, *, use_pallas: bool = False,
            move_budget: int = 256) -> Tuple[Dict, jax.Array, jax.Array]:
    """Fused two-direction migration: compress the masks to budgeted
    mover lists (one sort), plan HOT then COLD destinations off the free
    rings (so cold movers can claim slots hot movers vacate, same as the
    old sequential passes), then execute every payload copy in ONE data
    movement of 2*budget rows. Returns (state, n_hot, n_cold).

    Work is compute-proportional: besides the classification masks (an
    elementwise table sweep) and the selection sort, every gather/scatter
    here is O(move_budget) — pool size only enters through the closing
    restock. Movers beyond the budget stay eligible and move on a later
    window (the same deferral as a full destination region).

    Safety of the single copy: hot dsts are free HOT-region slots and cold
    dsts are free (possibly just-vacated) COLD-region slots, so all dsts
    are distinct; no cold src is ever a hot dst, so in hot-then-cold order
    no move reads a slot an earlier move wrote — the `migrate` kernel's
    sequential-grid contract, and trivially true for the functional jnp
    scatter (which gathers all sources pre-write).

    Carried allocator state stays consistent: the per-superblock
    occupancy counters and per-slot referenced bits move with the objects
    (src -1 / dst +1), and the free-slot rings are RESTOCKED from the
    post-move slot-owner array in ascending slot order — the
    once-per-window sweep that restores the dense-first allocation bias
    (docs/allocator.md)."""
    m = int(move_budget) or cfg.max_objects
    m = max(1, min(m, cfg.max_objects))
    ids_h, okm_h, ids_c, okm_c = _select_movers(to_hot, to_cold, m)
    state, src_h, dst_h, ok_h = _plan_moves(cfg, state, ids_h, okm_h,
                                            ot.HOT)
    state, src_c, dst_c, ok_c = _plan_moves(cfg, state, ids_c, okm_c,
                                            ot.COLD)
    src = jnp.concatenate([src_h, src_c])
    dst = jnp.concatenate([dst_h, dst_c])
    ok = jnp.concatenate([ok_h, ok_c])
    # masked moves route BOTH ends to the pool's permanent scratch row
    # (index n_slots, all-zero at rest): the kernel copies the scratch row
    # onto itself and the jnp oracle scatters zeros onto it, so the row
    # stays zero and both paths remain bit-identical with no per-pass pad
    # copy of the pool
    if use_pallas:
        from repro.kernels import ops as kops
        data = kops.migrate(state["data"], src, dst, ok,
                            has_scratch_row=True)
    else:
        data = state["data"].at[jnp.where(ok, dst, cfg.n_slots)].set(
            state["data"][jnp.where(ok, src, cfg.n_slots)], mode="drop")
    free_q, free_head, free_count = fl.restock(cfg, state["free_q"],
                                               state["slot_owner"])
    state = dict(state, data=data, free_q=free_q, free_head=free_head,
                 free_count=free_count)
    return state, jnp.sum(ok_h), jnp.sum(ok_c)


def collect(pool_cfg: pl.PoolConfig, col_cfg: CollectorConfig,
            state: Dict) -> Tuple[Dict, Dict[str, jax.Array]]:
    """One Object Collector pass. Returns (state, report)."""
    # one table sweep: CIW update + migration masks + ATC-veto diagnostic
    # (the access_scan kernel emits all four on the use_pallas path)
    new_tbl, to_hot, to_cold, skipped_atc = classify(pool_cfg, col_cfg,
                                                     state)
    state = dict(state, table=new_tbl)

    # fused two-direction migration, one data movement
    state, n_hot, n_cold = migrate(pool_cfg, state, to_hot, to_cold,
                                   use_pallas=col_cfg.use_pallas,
                                   move_budget=col_cfg.move_budget)

    # --- MIAD on the window's promotion rate ---
    new_ct, calm, rate, proactive_ok = policy.update(
        col_cfg.miad, state["ciw_threshold"], state["calm_windows"],
        state["win_promos"], state["win_accesses"])

    # --- mark uniformly-cold COLD-region superblocks as MADV_COLD
    #     candidates (frontend -> backend signal) ---
    stats = pl.superblock_stats(pool_cfg, state)
    cold_uniform = (stats["region"] == ot.COLD) & (stats["occupancy"] > 0) \
        & (~stats["referenced"]) & (state["sb_tier"] == pl.HBM)
    sb_evict = jnp.where(cold_uniform & (state["sb_evict"] == pl.NORMAL),
                         pl.CANDIDATE, state["sb_evict"]).astype(jnp.int8)

    # --- clear access bits + ATCs; advance epoch; reset window counters ---
    # (stats above were computed PRE-clear: backends must see the closing
    # window's referenced bits, or kswapd degenerates into the cap; the
    # carried slot_ref bits reset with the access bits they mirror)
    tbl = ot.clear_access_and_atc(state["table"])
    report = {
        "moved_to_hot": n_hot, "moved_to_cold": n_cold,
        "skipped_atc": skipped_atc,
        "promotion_rate": rate, "proactive_ok": proactive_ok,
        "ciw_threshold": new_ct,
        "win_accesses": state["win_accesses"],
        "win_faults": state["win_faults"],
        "sb_stats": dict(stats, evict=sb_evict),
    }
    state = dict(
        state, table=tbl, sb_evict=sb_evict, ciw_threshold=new_ct,
        calm_windows=calm, epoch=state["epoch"] + 1,
        slot_ref=jnp.zeros_like(state["slot_ref"]),
        armed=jnp.zeros((), jnp.bool_),
        win_accesses=jnp.zeros((), jnp.int32),
        win_promos=jnp.zeros((), jnp.int32),
        win_faults=jnp.zeros((), jnp.int32),
        total_moves=state["total_moves"] + (n_hot + n_cold).astype(jnp.int32))
    return state, report


def arm(state: Dict) -> Dict:
    """Arm the migration window: subsequent reads bump ATCs (the epoch-based
    activation of tracking — zero overhead when unarmed, paper §4)."""
    return dict(state, armed=jnp.ones((), jnp.bool_))


def compact_heap(pool_cfg: pl.PoolConfig, state: Dict, heap: int) -> Dict:
    """Repack a region densely (objects to region start, holes to the end).
    Out-of-place permutation — safe under any aliasing. A maintenance
    pass (not on the per-op path), so it rebuilds the carried allocator
    state wholesale: free rings restocked from the compacted owner array,
    occupancy recomputed from scratch."""
    lo, hi = pool_cfg.region(heap)
    owner = state["slot_owner"]
    seg = owner[lo:hi]
    live = seg >= 0
    csum = jnp.cumsum(live.astype(jnp.int32))
    new_rel = jnp.where(live, csum - 1, -1)
    src = jnp.arange(lo, hi, dtype=jnp.int32)
    dst = jnp.where(live, new_rel + lo, pool_cfg.n_slots)

    # dead entries target the scratch row; copy the (all-zero) scratch row
    # onto itself so its invariant survives the scatter
    data = state["data"].at[dst].set(
        state["data"][jnp.where(live, src, pool_cfg.n_slots)], mode="drop")
    new_seg_owner = jnp.full_like(seg, -1).at[
        jnp.where(live, new_rel, hi - lo)].set(seg, mode="drop")
    owner = owner.at[lo:hi].set(new_seg_owner)
    tbl = state["table"].at[jnp.where(live, seg, pool_cfg.max_objects)].set(
        ot.with_slot(state["table"][jnp.maximum(seg, 0)],
                     (new_rel + lo).astype(jnp.uint32)), mode="drop")
    # referenced bits ride the permutation
    seg_ref = state["slot_ref"][lo:hi]
    new_seg_ref = jnp.zeros_like(seg_ref).at[
        jnp.where(live, new_rel, hi - lo)].set(seg_ref, mode="drop")
    slot_ref = state["slot_ref"].at[lo:hi].set(new_seg_ref)
    free_q, free_head, free_count = fl.restock(pool_cfg, state["free_q"],
                                               owner)
    return dict(state, data=data, slot_owner=owner, table=tbl,
                slot_ref=slot_ref, free_q=free_q, free_head=free_head,
                free_count=free_count,
                sb_occ=pl.recompute_sb_occupancy(pool_cfg, owner))
