"""Object Collector — periodic scan + lock-free migration (paper §4).

Each collect pass, run between application steps (the migration window):

  1. Scan every table word: read access bits; update per-object CIW
     (Consecutive Inactive Windows).
  2. Classify (Fig. 5 state machine):
        accessed & heap in {NEW, COLD}         -> migrate to HOT
        ~accessed & CIW > C_t & heap in {NEW,HOT} -> migrate to COLD
  3. Migrate: an object moves ONLY if its ATC is zero (the paper's
     optimistic lock-free rule — an object observed in active use during
     the armed window is skipped and retried next pass; forward progress
     is never blocked).
  4. Destination slots are taken densely from the start of the target
     region, so HOT stays compact (huge-page-promotable) and COLD
     superblocks become uniformly cold.
  5. MIAD updates C_t from the window's promotion rate; access bits and
     ATCs are cleared; the epoch advances.

Everything is a fixed-shape array program: "no objects to move" is the
all-false mask, so the pass jits once and runs every window.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import object_table as ot
from repro.core import policy
from repro.core import pool as pl


@dataclasses.dataclass(frozen=True)
class CollectorConfig:
    miad: policy.MiadConfig = dataclasses.field(default_factory=policy.MiadConfig)
    # keep NEW objects in NEW until they show a verdict (paper: NEW heap
    # absorbs fresh allocations; they migrate on first classification)
    promote_new_on_access: bool = True


def _move_to_region(cfg: pl.PoolConfig, state: Dict, move_mask: jax.Array,
                    dest_heap: int) -> Tuple[Dict, jax.Array]:
    """Migrate all objects with move_mask=True into `dest_heap`'s region.
    Objects that don't fit (region full) are left in place (retried next
    window). Returns (state, n_moved)."""
    lo, hi = cfg.region(dest_heap)
    tbl = state["table"]
    ids = jnp.arange(cfg.max_objects, dtype=jnp.int32)
    words = tbl
    src_slot = ot.slot_of(words).astype(jnp.int32)

    # rank movers; grab that many free slots from the region (dense-first)
    rank = jnp.cumsum(move_mask.astype(jnp.int32)) - 1
    free = state["slot_owner"][lo:hi] == -1
    csum = jnp.cumsum(free.astype(jnp.int32))
    n_free = csum[-1]
    fr = jnp.where(free, csum - 1, hi - lo)
    slot_for_rank = jnp.full((hi - lo + 1,), 0, jnp.int32) \
        .at[fr].set(jnp.arange(hi - lo, dtype=jnp.int32), mode="drop")
    dst_rel = slot_for_rank[jnp.clip(rank, 0, hi - lo)]
    ok = move_mask & (rank < n_free) & (rank >= 0)
    dst_slot = jnp.where(ok, dst_rel + lo, src_slot)

    # data copy (functional: reads pre-move data, so src/dst aliasing with
    # in-region compaction is safe by construction)
    data = state["data"].at[jnp.where(ok, dst_slot, cfg.n_slots)].set(
        state["data"][src_slot], mode="drop")
    # slot ownership: clear src, claim dst
    owner = state["slot_owner"].at[jnp.where(ok, src_slot, cfg.n_slots)] \
        .set(-1, mode="drop")
    owner = owner.at[jnp.where(ok, dst_slot, cfg.n_slots)].set(
        ids, mode="drop")
    # table word: new slot + heap (flags preserved; cleared later in pass)
    new_words = ot.with_heap(ot.with_slot(words, dst_slot.astype(jnp.uint32)),
                             dest_heap)
    tbl = jnp.where(ok, new_words, tbl)
    return dict(state, data=data, slot_owner=owner, table=tbl), jnp.sum(ok)


def collect(pool_cfg: pl.PoolConfig, col_cfg: CollectorConfig,
            state: Dict) -> Tuple[Dict, Dict[str, jax.Array]]:
    """One Object Collector pass. Returns (state, report)."""
    tbl = state["table"]
    live = ot.is_live(tbl)
    acc = (ot.access_of(tbl) == 1) & live
    atc = ot.atc_of(tbl)
    heap = ot.heap_of(tbl)
    ct = jnp.floor(state["ciw_threshold"]).astype(jnp.uint32)

    # --- CIW update (accessed -> 0; idle -> +1, saturating) ---
    ciw = ot.ciw_of(tbl)
    ciw = jnp.where(acc, 0, jnp.minimum(ciw + 1, ot.CIW_SAT))
    ciw = jnp.where(live, ciw, 0)

    # --- classification (Fig. 5) ---
    to_hot = acc & ((heap == ot.COLD) |
                    ((heap == ot.NEW) & col_cfg.promote_new_on_access))
    to_cold = (~acc) & (ciw > ct) & ((heap == ot.NEW) | (heap == ot.HOT))
    movable = live & (atc == 0)          # the lock-free rule
    to_hot &= movable
    to_cold &= movable

    # write back CIW before moving (moves preserve flag bits)
    tbl = (tbl & ~(ot.CIW_MASK << ot.CIW_SHIFT)) | \
        (ciw.astype(jnp.uint32) << ot.CIW_SHIFT)
    state = dict(state, table=tbl)

    state, n_hot = _move_to_region(pool_cfg, state, to_hot, ot.HOT)
    state, n_cold = _move_to_region(pool_cfg, state, to_cold, ot.COLD)
    skipped_atc = jnp.sum(live & (atc > 0) &
                          (acc | ((ciw > ct) & (heap != ot.COLD))))

    # --- MIAD on the window's promotion rate ---
    new_ct, calm, rate, proactive_ok = policy.update(
        col_cfg.miad, state["ciw_threshold"], state["calm_windows"],
        state["win_promos"], state["win_accesses"])

    # --- mark uniformly-cold COLD-region superblocks as MADV_COLD
    #     candidates (frontend -> backend signal) ---
    stats = pl.superblock_stats(pool_cfg, state)
    cold_uniform = (stats["region"] == ot.COLD) & (stats["occupancy"] > 0) \
        & (~stats["referenced"]) & (state["sb_tier"] == pl.HBM)
    sb_evict = jnp.where(cold_uniform & (state["sb_evict"] == pl.NORMAL),
                         pl.CANDIDATE, state["sb_evict"]).astype(jnp.int8)

    # --- clear access bits + ATCs; advance epoch; reset window counters ---
    # (stats above were computed PRE-clear: backends must see the closing
    # window's referenced bits, or kswapd degenerates into the cap)
    tbl = ot.clear_access_and_atc(state["table"])
    report = {
        "moved_to_hot": n_hot, "moved_to_cold": n_cold,
        "skipped_atc": skipped_atc,
        "promotion_rate": rate, "proactive_ok": proactive_ok,
        "ciw_threshold": new_ct,
        "win_accesses": state["win_accesses"],
        "win_faults": state["win_faults"],
        "sb_stats": dict(stats, evict=sb_evict),
    }
    state = dict(
        state, table=tbl, sb_evict=sb_evict, ciw_threshold=new_ct,
        calm_windows=calm, epoch=state["epoch"] + 1,
        armed=jnp.zeros((), jnp.bool_),
        win_accesses=jnp.zeros((), jnp.int32),
        win_promos=jnp.zeros((), jnp.int32),
        win_faults=jnp.zeros((), jnp.int32),
        total_moves=state["total_moves"] + (n_hot + n_cold).astype(jnp.int32))
    return state, report


def arm(state: Dict) -> Dict:
    """Arm the migration window: subsequent reads bump ATCs (the epoch-based
    activation of tracking — zero overhead when unarmed, paper §4)."""
    return dict(state, armed=jnp.ones((), jnp.bool_))


def compact_heap(pool_cfg: pl.PoolConfig, state: Dict, heap: int) -> Dict:
    """Repack a region densely (objects to region start, holes to the end).
    Out-of-place permutation — safe under any aliasing."""
    lo, hi = pool_cfg.region(heap)
    owner = state["slot_owner"]
    seg = owner[lo:hi]
    live = seg >= 0
    csum = jnp.cumsum(live.astype(jnp.int32))
    new_rel = jnp.where(live, csum - 1, -1)
    src = jnp.arange(lo, hi, dtype=jnp.int32)
    dst = jnp.where(live, new_rel + lo, pool_cfg.n_slots)

    data = state["data"].at[dst].set(state["data"][src], mode="drop")
    new_seg_owner = jnp.full_like(seg, -1).at[
        jnp.where(live, new_rel, hi - lo)].set(seg, mode="drop")
    owner = owner.at[src - lo + lo].set(new_seg_owner)  # in-region overwrite
    tbl = state["table"].at[jnp.where(live, seg, pool_cfg.max_objects)].set(
        ot.with_slot(state["table"][jnp.maximum(seg, 0)],
                     (new_rel + lo).astype(jnp.uint32)), mode="drop")
    return dict(state, data=data, slot_owner=owner, table=tbl)
