"""Object table — the JAX analog of HADES' tagged pointers ("guides").

Each managed object has one packed uint32 word. The paper packs metadata into
unused high-order bits of the 64-bit pointer; here all access flows through an
explicit logical-id -> physical-slot indirection, so the metadata lives in the
indirection word itself:

    [ ciw:5 | atc:4 | access:1 | heap:2 | slot:20 ]   (MSB..LSB)

  slot   — physical slot index in the pool (up to 2^20 slots)
  heap   — NEW(0) / HOT(1) / COLD(2) / FREE(3)
  access — access bit, set on dereference (idempotent scatter-or)
  atc    — Active Thread Count analog: saturating counter of accesses while a
           migration window is armed; an object with atc > 0 is never moved
           (the paper's optimistic lock-free rule)
  ciw    — Consecutive Inactive Windows, saturating at 31

All ops are vectorized over uint32 arrays and jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SLOT_BITS = 20
HEAP_BITS = 2
ACCESS_BITS = 1
ATC_BITS = 4
CIW_BITS = 5
assert SLOT_BITS + HEAP_BITS + ACCESS_BITS + ATC_BITS + CIW_BITS == 32

SLOT_SHIFT = 0
HEAP_SHIFT = SLOT_BITS
ACCESS_SHIFT = HEAP_SHIFT + HEAP_BITS
ATC_SHIFT = ACCESS_SHIFT + ACCESS_BITS
CIW_SHIFT = ATC_SHIFT + ATC_BITS

SLOT_MASK = jnp.uint32((1 << SLOT_BITS) - 1)
HEAP_MASK = jnp.uint32((1 << HEAP_BITS) - 1)
ACCESS_MASK = jnp.uint32(1)
ATC_MASK = jnp.uint32((1 << ATC_BITS) - 1)
CIW_MASK = jnp.uint32((1 << CIW_BITS) - 1)

MAX_SLOTS = 1 << SLOT_BITS
CIW_SAT = (1 << CIW_BITS) - 1
ATC_SAT = (1 << ATC_BITS) - 1

# heap ids
NEW, HOT, COLD, FREE = 0, 1, 2, 3


def pack(slot, heap, access=0, atc=0, ciw=0) -> jax.Array:
    """Pack fields -> uint32 word(s)."""
    slot = jnp.asarray(slot, jnp.uint32)
    heap = jnp.asarray(heap, jnp.uint32)
    access = jnp.asarray(access, jnp.uint32)
    atc = jnp.asarray(atc, jnp.uint32)
    ciw = jnp.asarray(ciw, jnp.uint32)
    return ((slot & SLOT_MASK)
            | ((heap & HEAP_MASK) << HEAP_SHIFT)
            | ((access & ACCESS_MASK) << ACCESS_SHIFT)
            | ((atc & ATC_MASK) << ATC_SHIFT)
            | ((ciw & CIW_MASK) << CIW_SHIFT))


def slot_of(w): return (w >> SLOT_SHIFT) & SLOT_MASK
def heap_of(w): return (w >> HEAP_SHIFT) & HEAP_MASK
def access_of(w): return (w >> ACCESS_SHIFT) & ACCESS_MASK
def atc_of(w): return (w >> ATC_SHIFT) & ATC_MASK
def ciw_of(w): return (w >> CIW_SHIFT) & CIW_MASK


def with_slot(w, slot):
    return (w & ~SLOT_MASK) | (jnp.asarray(slot, jnp.uint32) & SLOT_MASK)


def with_heap(w, heap):
    return (w & ~(HEAP_MASK << HEAP_SHIFT)) | \
        ((jnp.asarray(heap, jnp.uint32) & HEAP_MASK) << HEAP_SHIFT)


def with_access(w, access):
    return (w & ~(ACCESS_MASK << ACCESS_SHIFT)) | \
        ((jnp.asarray(access, jnp.uint32) & ACCESS_MASK) << ACCESS_SHIFT)


def with_atc(w, atc):
    return (w & ~(ATC_MASK << ATC_SHIFT)) | \
        ((jnp.asarray(atc, jnp.uint32) & ATC_MASK) << ATC_SHIFT)


def with_ciw(w, ciw):
    return (w & ~(CIW_MASK << CIW_SHIFT)) | \
        ((jnp.asarray(ciw, jnp.uint32) & CIW_MASK) << CIW_SHIFT)


def free_word() -> jax.Array:
    """A table word denoting 'no object' (heap=FREE, slot=0)."""
    return pack(0, FREE)


def make_table(num_objects: int) -> jax.Array:
    return jnp.full((num_objects,), free_word(), jnp.uint32)


def is_live(w) -> jax.Array:
    return heap_of(w) != FREE


def record_access(table: jax.Array, obj_ids: jax.Array,
                  armed: bool | jax.Array = False) -> jax.Array:
    """Set access bits for obj_ids (idempotent — the paper skips the
    store when already set). When a migration window is `armed`, also
    bump the saturating ATC — the scope-guard analog. Invalid ids (< 0)
    are dropped (NOT redirected to id 0 with a no-op update: a batch
    holding both a padding entry and a real access to object 0 would
    otherwise write conflicting words to index 0).

    Shape of the update: one K-sized scatter into a FRESH boolean hit
    mask, then an elementwise rewrite of the table. Scattering into the
    table directly would read-and-write a scan-carried buffer in one
    step, which defeats XLA's in-place aliasing of the carry (the whole
    table gets copied every step); the armed branch is folded in as a
    mask instead of a `lax.cond` for the same reason. Duplicate ids bump
    the ATC once per batch, exactly like the old scatter-max."""
    n = table.shape[0]
    dst = jnp.where(obj_ids >= 0, obj_ids, n)
    hit = jnp.zeros((n,), jnp.bool_).at[dst].set(True, mode="drop")
    word = table | (ACCESS_MASK << ACCESS_SHIFT)
    bump = hit & jnp.asarray(armed).astype(bool)
    word = jnp.where(bump, with_atc(word, jnp.minimum(atc_of(word) + 1,
                                                      ATC_SAT)), word)
    return jnp.where(hit, word, table)


def clear_access_and_atc(table: jax.Array) -> jax.Array:
    mask = ~((ACCESS_MASK << ACCESS_SHIFT) | (ATC_MASK << ATC_SHIFT))
    return table & mask
