"""HadesPool — the managed object heap (fixed-size objects, jit-native).

This is the framework-facing realization of the paper's custom allocator +
three-heap layout (Fig. 5). One pool manages `max_objects` logical objects,
each occupying exactly one physical slot of `slot_words` elements (KV blocks,
embedding rows and expert slabs are all fixed-size objects, so the
fixed-slot restriction costs nothing in the framework; the byte-granular
CrestKV simulator in `core/simheap.py` handles variable-size objects for the
paper's YCSB evaluation).

Address-space layout (slot indices):

    [0 .............. new_end) NEW   heap  — fresh allocations
    [new_end ........ hot_end) HOT   heap  — dense, "huge-page" region
    [hot_end ........ n_slots) COLD  heap  — uniform-cold, reclaim target

Regions are superblock-aligned; a superblock (`sb_slots` contiguous slots)
is the reclamation/hugepage unit — the "page" that backends manage. The
entire pool state is a pytree of arrays, so every operation jits and shards.

Tier/fault model (CPU-runnable stand-in for HBM/host tiers; on a real TPU
the demotion would be a device_put to `memory_kind="pinned_host"`):
  sb_tier:  0 = HBM, 1 = HOST (paged out)
  sb_evict: 0 = NORMAL, 1 = CANDIDATE (MADV_COLD), 2 = PAGED_OUT (PAGEOUT)
Reading a slot whose superblock is HOST-resident is a *page fault*: the
superblock is promoted back to HBM and the fault counter increments — the
signal the MIAD policy keeps below its target.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import object_table as ot

# tiers / evict states
HBM, HOST = 0, 1
NORMAL, CANDIDATE, PAGED_OUT = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static geometry (hashable; closed over by jitted fns)."""
    max_objects: int
    slot_words: int            # elements per object slot
    sb_slots: int              # slots per superblock (reclamation unit)
    page_slots: int            # slots per 4-KiB-analog page (metric unit)
    new_sbs: int               # superblocks in the NEW region
    hot_sbs: int               # superblocks in the HOT region
    cold_sbs: int              # superblocks in the COLD region
    dtype: str = "float32"
    word_bytes: int = 4

    @property
    def n_sbs(self) -> int:
        return self.new_sbs + self.hot_sbs + self.cold_sbs

    @property
    def n_slots(self) -> int:
        return self.n_sbs * self.sb_slots

    @property
    def sb_bytes(self) -> int:
        return self.sb_slots * self.slot_words * self.word_bytes

    @property
    def slot_bytes(self) -> int:
        return self.slot_words * self.word_bytes

    def region(self, heap: int) -> Tuple[int, int]:
        """[start, end) slot range of a heap region."""
        new_end = self.new_sbs * self.sb_slots
        hot_end = new_end + self.hot_sbs * self.sb_slots
        if heap == ot.NEW:
            return 0, new_end
        if heap == ot.HOT:
            return new_end, hot_end
        if heap == ot.COLD:
            return hot_end, self.n_slots
        raise ValueError(heap)

    def sb_region_ids(self) -> jnp.ndarray:
        """Per-superblock heap-region id [n_sbs]."""
        return jnp.concatenate([
            jnp.full((self.new_sbs,), ot.NEW, jnp.int8),
            jnp.full((self.hot_sbs,), ot.HOT, jnp.int8),
            jnp.full((self.cold_sbs,), ot.COLD, jnp.int8)])


def make_config(max_objects: int, slot_words: int, *, sb_slots: int = 64,
                page_slots: int = 8, new_frac: float = 0.125,
                hot_frac: float = 0.375, slack: float = 1.5,
                dtype: str = "float32") -> PoolConfig:
    """Size a pool with `slack`x physical slots over max_objects, split into
    NEW/HOT/COLD regions by fraction."""
    n_slots = int(max_objects * slack)
    n_sbs = max(3, -(-n_slots // sb_slots))
    new_sbs = max(1, int(n_sbs * new_frac))
    hot_sbs = max(1, int(n_sbs * hot_frac))
    cold_sbs = max(1, n_sbs - new_sbs - hot_sbs)
    word_bytes = jnp.dtype(dtype).itemsize
    return PoolConfig(max_objects=max_objects, slot_words=slot_words,
                      sb_slots=sb_slots, page_slots=page_slots,
                      new_sbs=new_sbs, hot_sbs=hot_sbs, cold_sbs=cold_sbs,
                      dtype=dtype, word_bytes=word_bytes)


def init(cfg: PoolConfig) -> Dict[str, jax.Array]:
    """Fresh pool state (a pytree dict — shardable, checkpointable).

    The data array carries ONE extra row (index `n_slots`) — a permanent
    scratch row for the migrate kernel's masked moves, so the collector
    never pays a whole-pool pad copy to append one per pass. Invariant:
    the scratch row is all-zero at rest. Every masked/dead scatter that
    targets index `n_slots` must therefore write zeros (or copy the
    scratch row onto itself), keeping the jnp oracle and the Pallas mover
    bit-identical including the scratch bytes."""
    return {
        "data": jnp.zeros((cfg.n_slots + 1, cfg.slot_words),
                          jnp.dtype(cfg.dtype)),
        "table": ot.make_table(cfg.max_objects),
        "slot_owner": jnp.full((cfg.n_slots,), -1, jnp.int32),
        "sb_tier": jnp.zeros((cfg.n_sbs,), jnp.int8),
        "sb_evict": jnp.zeros((cfg.n_sbs,), jnp.int8),
        # MIAD-controlled demotion threshold C_t (float for mult. updates)
        "ciw_threshold": jnp.asarray(3.0, jnp.float32),
        # escalation gate: consecutive windows with promotion rate < target
        "calm_windows": jnp.zeros((), jnp.int32),
        "epoch": jnp.zeros((), jnp.int32),
        "armed": jnp.zeros((), jnp.bool_),   # migration window armed (ATC on)
        # window counters (reset each collect)
        "win_accesses": jnp.zeros((), jnp.int32),
        "win_promos": jnp.zeros((), jnp.int32),   # COLD-heap hits
        "win_faults": jnp.zeros((), jnp.int32),   # HOST-tier page faults
        # lifetime counters
        "total_faults": jnp.zeros((), jnp.int32),
        "total_moves": jnp.zeros((), jnp.int32),
        # tiering-backend carried state (backend.Backend protocol). Empty
        # for stateless backends; Engine.init / kvcache.init replace it
        # with backend.init(cfg) so stateful backends (mglru, promote)
        # ride the fused-window scan carry. Every pool op passes it
        # through untouched.
        "bstate": {},
    }


# ---------------------------------------------------------------------------
# Allocation — bump into the NEW region's free slots
# ---------------------------------------------------------------------------
def _take_free_slots(slot_owner: jax.Array, lo: int, hi: int,
                     k: int) -> Tuple[jax.Array, jax.Array]:
    """First `k` free slot indices in [lo, hi). Returns (slots [k], ok [k]);
    slots where ok=False are invalid (region full)."""
    free = slot_owner[lo:hi] == -1
    # rank of each free slot among free slots
    csum = jnp.cumsum(free.astype(jnp.int32))
    n_free = csum[-1] if free.shape[0] else jnp.zeros((), jnp.int32)
    # slot_for_rank[r] = index of the r-th free slot
    ranks = jnp.where(free, csum - 1, hi - lo)
    slot_for_rank = jnp.full((hi - lo + 1,), -1, jnp.int32) \
        .at[ranks].set(jnp.arange(hi - lo, dtype=jnp.int32), mode="drop")
    want = jnp.arange(k, dtype=jnp.int32)
    ok = want < n_free
    slots = jnp.where(ok, slot_for_rank[jnp.minimum(want, hi - lo)], 0) + lo
    return slots, ok


def _alloc_order(cfg: PoolConfig) -> jnp.ndarray:
    """Slot visit order for allocation: NEW region first (fresh objects
    belong there), spilling into COLD then HOT when NEW is full — a real
    allocator never fails while the pool has space."""
    spans = [cfg.region(ot.NEW), cfg.region(ot.COLD), cfg.region(ot.HOT)]
    return jnp.concatenate([jnp.arange(lo, hi, dtype=jnp.int32)
                            for lo, hi in spans])


def heap_of_slot(cfg: PoolConfig, slot: jax.Array) -> jax.Array:
    """Region id a physical slot belongs to (static boundaries)."""
    new_end = cfg.region(ot.NEW)[1]
    hot_end = cfg.region(ot.HOT)[1]
    return jnp.where(slot < new_end, ot.NEW,
                     jnp.where(slot < hot_end, ot.HOT, ot.COLD)
                     ).astype(jnp.uint32)


def alloc(cfg: PoolConfig, state: Dict, obj_ids: jax.Array,
          values: jax.Array) -> Dict:
    """Allocate `obj_ids` (shape [k], int32) in the NEW heap (spilling to
    COLD/HOT when full) and write `values` [k, slot_words]. Ids already
    live are re-written in place (update semantics). Ids < 0 ignored."""
    k = obj_ids.shape[0]
    tbl = state["table"]
    ids_safe = jnp.maximum(obj_ids, 0)
    words = tbl[ids_safe]
    live = ot.is_live(words) & (obj_ids >= 0)
    need = (~live) & (obj_ids >= 0)

    # free slots in allocation order (NEW -> COLD -> HOT)
    order = _alloc_order(cfg)
    free = state["slot_owner"][order] == -1
    csum = jnp.cumsum(free.astype(jnp.int32))
    n_free = csum[-1]
    fr = jnp.where(free, csum - 1, cfg.n_slots)
    slot_for_rank = jnp.zeros((cfg.n_slots + 1,), jnp.int32) \
        .at[fr].set(order, mode="drop")
    # rank each needed alloc among needed allocs -> pick that free slot
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    ok_new = need & (rank < n_free) & (rank >= 0)
    new_slot = slot_for_rank[jnp.clip(rank, 0, cfg.n_slots)]

    # existing objects keep their slot; new ones take the found slot
    slot = jnp.where(ok_new, new_slot, ot.slot_of(words).astype(jnp.int32))
    do = live | ok_new

    new_words = jnp.where(
        ok_new, ot.pack(new_slot.astype(jnp.uint32),
                        heap_of_slot(cfg, new_slot), access=1),
        # live update: set access bit
        words | (ot.ACCESS_MASK << ot.ACCESS_SHIFT))
    tbl = tbl.at[ids_safe].set(jnp.where(do, new_words, tbl[ids_safe]),
                               mode="drop")
    owner = state["slot_owner"].at[jnp.where(ok_new, new_slot, cfg.n_slots)] \
        .set(jnp.where(ok_new, obj_ids, -1), mode="drop")
    data = state["data"].at[jnp.where(do, slot, cfg.n_slots)].set(
        jnp.where(do[:, None], values.astype(state["data"].dtype),
                  0), mode="drop")
    return dict(state, table=tbl, slot_owner=owner, data=data,
                win_accesses=state["win_accesses"] + jnp.sum(do))


# ---------------------------------------------------------------------------
# Read / write — every access flows through the table (the "dereference")
# ---------------------------------------------------------------------------
def read(cfg: PoolConfig, state: Dict, obj_ids: jax.Array
         ) -> Tuple[jax.Array, Dict]:
    """Gather object payloads for `obj_ids` [k] (−1 entries return zeros).
    This is the paper's pointer dereference: it sets the access bit, bumps
    the ATC when a migration window is armed, counts COLD-heap promotions,
    and faults-in any HOST-resident superblock it touches."""
    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = state["table"][ids]
    live = ot.is_live(words) & valid
    slots = ot.slot_of(words).astype(jnp.int32)
    vals = jnp.where(live[:, None], state["data"][slots], 0)

    tbl = ot.record_access(state["table"], jnp.where(live, obj_ids, -1),
                           armed=state["armed"])

    # --- fault / promotion accounting ---
    sbs = slots // cfg.sb_slots
    on_host = live & (state["sb_tier"][sbs] == HOST)
    # unique faulted superblocks
    fault_mask = jnp.zeros((cfg.n_sbs,), jnp.bool_).at[
        jnp.where(on_host, sbs, cfg.n_sbs)].set(True, mode="drop")
    n_faults = jnp.sum(fault_mask).astype(jnp.int32)
    # fault-in: promote superblock back to HBM
    sb_tier = jnp.where(fault_mask, HBM, state["sb_tier"]).astype(jnp.int8)
    sb_evict = jnp.where(fault_mask, NORMAL, state["sb_evict"]).astype(jnp.int8)

    promos = jnp.sum(live & (ot.heap_of(words) == ot.COLD)).astype(jnp.int32)
    accs = jnp.sum(live).astype(jnp.int32)

    state = dict(state, table=tbl, sb_tier=sb_tier, sb_evict=sb_evict,
                 win_accesses=state["win_accesses"] + accs,
                 win_promos=state["win_promos"] + promos,
                 win_faults=state["win_faults"] + n_faults,
                 total_faults=state["total_faults"] + n_faults)
    return vals, state


def write(cfg: PoolConfig, state: Dict, obj_ids: jax.Array,
          values: jax.Array) -> Dict:
    """Scatter payloads to live objects (a store is also an access)."""
    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = state["table"][ids]
    live = ot.is_live(words) & valid
    slots = ot.slot_of(words).astype(jnp.int32)
    # dead/padding entries are routed to the scratch row (index n_slots)
    # and must write ZEROS to preserve its all-zero invariant
    data = state["data"].at[jnp.where(live, slots, cfg.n_slots)].set(
        jnp.where(live[:, None], values.astype(state["data"].dtype), 0),
        mode="drop")
    tbl = ot.record_access(state["table"], jnp.where(live, obj_ids, -1),
                           armed=state["armed"])
    promos = jnp.sum(live & (ot.heap_of(words) == ot.COLD)).astype(jnp.int32)
    return dict(state, data=data, table=tbl,
                win_accesses=state["win_accesses"] + jnp.sum(live),
                win_promos=state["win_promos"] + promos)


def free(cfg: PoolConfig, state: Dict, obj_ids: jax.Array) -> Dict:
    """Release objects (slot returns to its region's free pool)."""
    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = state["table"][ids]
    live = ot.is_live(words) & valid
    slots = ot.slot_of(words).astype(jnp.int32)
    owner = state["slot_owner"].at[jnp.where(live, slots, cfg.n_slots)] \
        .set(-1, mode="drop")
    tbl = state["table"].at[jnp.where(live, ids, cfg.max_objects)].set(
        ot.free_word(), mode="drop")
    return dict(state, slot_owner=owner, table=tbl)


# ---------------------------------------------------------------------------
# Superblock summaries (the ONLY view backends get — object-oblivious)
# ---------------------------------------------------------------------------
def sb_occupancy(cfg: PoolConfig, state: Dict) -> jax.Array:
    """Per-superblock live-slot count [n_sbs], from the slot-owner array
    alone — no object-table gather. The cheap shared input for the
    RSS/host gauges and the backend path (the referenced bits in
    `superblock_stats` are the expensive part; occupancy is not)."""
    live_slot = state["slot_owner"] >= 0
    sb_of_slot = jnp.arange(cfg.n_slots) // cfg.sb_slots
    return jnp.zeros((cfg.n_sbs,), jnp.int32).at[sb_of_slot].add(
        live_slot.astype(jnp.int32))


def superblock_stats(cfg: PoolConfig, state: Dict) -> Dict[str, jax.Array]:
    """Per-superblock: occupancy, referenced (any access bit within),
    region id, tier, evict state. This is the page-table-level view the
    paper's unmodified backends consume."""
    owner = state["slot_owner"]
    live_slot = owner >= 0
    sb_of_slot = jnp.arange(cfg.n_slots) // cfg.sb_slots
    acc_obj = ot.access_of(state["table"]) == 1
    slot_acc = live_slot & acc_obj[jnp.maximum(owner, 0)]
    ref = jnp.zeros((cfg.n_sbs,), jnp.bool_).at[sb_of_slot].max(slot_acc)
    return {"occupancy": sb_occupancy(cfg, state), "referenced": ref,
            "region": cfg.sb_region_ids(),
            "tier": state["sb_tier"], "evict": state["sb_evict"]}


def rss_bytes(cfg: PoolConfig, state: Dict) -> jax.Array:
    """Resident (HBM-tier) bytes: occupied superblocks still in HBM."""
    occ = sb_occupancy(cfg, state)
    resident = (occ > 0) & (state["sb_tier"] == HBM)
    return jnp.sum(resident).astype(jnp.float32) * float(cfg.sb_bytes)


def host_bytes(cfg: PoolConfig, state: Dict) -> jax.Array:
    occ = sb_occupancy(cfg, state)
    out = (occ > 0) & (state["sb_tier"] == HOST)
    return jnp.sum(out).astype(jnp.float32) * float(cfg.sb_bytes)
