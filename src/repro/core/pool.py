"""HadesPool — the managed object heap (fixed-size objects, jit-native).

This is the framework-facing realization of the paper's custom allocator +
three-heap layout (Fig. 5). One pool manages `max_objects` logical objects,
each occupying exactly one physical slot of `slot_words` elements (KV blocks,
embedding rows and expert slabs are all fixed-size objects, so the
fixed-slot restriction costs nothing in the framework; the byte-granular
CrestKV simulator in `core/simheap.py` handles variable-size objects for the
paper's YCSB evaluation).

Address-space layout (slot indices):

    [0 .............. new_end) NEW   heap  — fresh allocations
    [new_end ........ hot_end) HOT   heap  — dense, "huge-page" region
    [hot_end ........ n_slots) COLD  heap  — uniform-cold, reclaim target

Regions are superblock-aligned; a superblock (`sb_slots` contiguous slots)
is the reclamation/hugepage unit — the "page" that backends manage. The
entire pool state is a pytree of arrays, so every operation jits and shards.

Tier/fault model (CPU-runnable stand-in for HBM/host tiers; on a real TPU
the demotion would be a device_put to `memory_kind="pinned_host"`):
  sb_tier:  0 = HBM, 1 = HOST (paged out)
  sb_evict: 0 = NORMAL, 1 = CANDIDATE (MADV_COLD), 2 = PAGED_OUT (PAGEOUT)
Reading a slot whose superblock is HOST-resident is a *page fault*: the
superblock is promoted back to HBM and the fault counter increments — the
signal the MIAD policy keeps below its target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import freelist as fl
from repro.core import object_table as ot

# tiers / evict states
HBM, HOST = 0, 1
NORMAL, CANDIDATE, PAGED_OUT = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static geometry (hashable; closed over by jitted fns)."""
    max_objects: int
    slot_words: int            # elements per object slot
    sb_slots: int              # slots per superblock (reclamation unit)
    page_slots: int            # slots per 4-KiB-analog page (metric unit)
    new_sbs: int               # superblocks in the NEW region
    hot_sbs: int               # superblocks in the HOT region
    cold_sbs: int              # superblocks in the COLD region
    dtype: str = "float32"
    word_bytes: int = 4

    @property
    def n_sbs(self) -> int:
        return self.new_sbs + self.hot_sbs + self.cold_sbs

    @property
    def n_slots(self) -> int:
        return self.n_sbs * self.sb_slots

    @property
    def sb_bytes(self) -> int:
        return self.sb_slots * self.slot_words * self.word_bytes

    @property
    def slot_bytes(self) -> int:
        return self.slot_words * self.word_bytes

    def region(self, heap: int) -> Tuple[int, int]:
        """[start, end) slot range of a heap region."""
        new_end = self.new_sbs * self.sb_slots
        hot_end = new_end + self.hot_sbs * self.sb_slots
        if heap == ot.NEW:
            return 0, new_end
        if heap == ot.HOT:
            return new_end, hot_end
        if heap == ot.COLD:
            return hot_end, self.n_slots
        raise ValueError(heap)

    def sb_region_ids(self) -> jnp.ndarray:
        """Per-superblock heap-region id [n_sbs]."""
        return jnp.concatenate([
            jnp.full((self.new_sbs,), ot.NEW, jnp.int8),
            jnp.full((self.hot_sbs,), ot.HOT, jnp.int8),
            jnp.full((self.cold_sbs,), ot.COLD, jnp.int8)])


def make_config(max_objects: int, slot_words: int, *, sb_slots: int = 64,
                page_slots: int = 8, new_frac: float = 0.125,
                hot_frac: float = 0.375, slack: float = 1.5,
                dtype: str = "float32") -> PoolConfig:
    """Size a pool with `slack`x physical slots over max_objects, split into
    NEW/HOT/COLD regions by fraction."""
    n_slots = int(max_objects * slack)
    n_sbs = max(3, -(-n_slots // sb_slots))
    new_sbs = max(1, int(n_sbs * new_frac))
    hot_sbs = max(1, int(n_sbs * hot_frac))
    cold_sbs = max(1, n_sbs - new_sbs - hot_sbs)
    word_bytes = jnp.dtype(dtype).itemsize
    return PoolConfig(max_objects=max_objects, slot_words=slot_words,
                      sb_slots=sb_slots, page_slots=page_slots,
                      new_sbs=new_sbs, hot_sbs=hot_sbs, cold_sbs=cold_sbs,
                      dtype=dtype, word_bytes=word_bytes)


def init(cfg: PoolConfig) -> Dict[str, jax.Array]:
    """Fresh pool state (a pytree dict — shardable, checkpointable).

    The data array carries ONE extra row (index `n_slots`) — a permanent
    scratch row for the migrate kernel's masked moves, so the collector
    never pays a whole-pool pad copy to append one per pass. Invariant:
    the scratch row is all-zero at rest. Every masked/dead scatter that
    targets index `n_slots` must therefore write zeros (or copy the
    scratch row onto itself), keeping the jnp oracle and the Pallas mover
    bit-identical including the scratch bytes.

    Allocator/occupancy state is CARRIED (docs/allocator.md): the
    per-region free-slot rings (`free_q`/`free_head`/`free_count`,
    core/freelist.py) make alloc/free O(K) in the batch size, and
    `sb_occ` tracks per-superblock live-slot counts incrementally
    (alloc +1 / free -1 / migrate +-1), so the RSS/host gauges and
    `superblock_stats` read O(n_sbs) counters instead of re-scanning
    all slots."""
    free_q, free_head, free_count = fl.seed(cfg)
    return {
        "data": jnp.zeros((cfg.n_slots + 1, cfg.slot_words),
                          jnp.dtype(cfg.dtype)),
        "table": ot.make_table(cfg.max_objects),
        "slot_owner": jnp.full((cfg.n_slots,), -1, jnp.int32),
        # carried free-slot rings (core/freelist.py): O(K) alloc/free,
        # restocked dense-first by the collector each window
        "free_q": free_q,
        "free_head": free_head,
        "free_count": free_count,
        # carried per-superblock live-slot counts (incremental)
        "sb_occ": jnp.zeros((cfg.n_sbs,), jnp.int32),
        # carried per-slot referenced bits: set at access time (O(K)),
        # moved with migrations, zeroed each collect — makes the
        # backend's per-superblock `referenced` stats an elementwise
        # reshape instead of an O(n_slots) gather+scatter per window
        "slot_ref": jnp.zeros((cfg.n_slots,), jnp.bool_),
        "sb_tier": jnp.zeros((cfg.n_sbs,), jnp.int8),
        "sb_evict": jnp.zeros((cfg.n_sbs,), jnp.int8),
        # MIAD-controlled demotion threshold C_t (float for mult. updates)
        "ciw_threshold": jnp.asarray(3.0, jnp.float32),
        # escalation gate: consecutive windows with promotion rate < target
        "calm_windows": jnp.zeros((), jnp.int32),
        "epoch": jnp.zeros((), jnp.int32),
        "armed": jnp.zeros((), jnp.bool_),   # migration window armed (ATC on)
        # window counters (reset each collect)
        "win_accesses": jnp.zeros((), jnp.int32),
        "win_promos": jnp.zeros((), jnp.int32),   # COLD-heap hits
        "win_faults": jnp.zeros((), jnp.int32),   # HOST-tier page faults
        # lifetime counters
        "total_faults": jnp.zeros((), jnp.int32),
        "total_moves": jnp.zeros((), jnp.int32),
        # tiering-backend carried state (backend.Backend protocol). Empty
        # for stateless backends; Engine.init / kvcache.init replace it
        # with backend.init(cfg) so stateful backends (mglru, promote)
        # ride the fused-window scan carry. Every pool op passes it
        # through untouched.
        "bstate": {},
    }


# ---------------------------------------------------------------------------
# Pool ops — ONE mask-parameterized transition (O(K) per op)
# ---------------------------------------------------------------------------
# op codes (also the engine's batched-trace encoding)
OP_READ, OP_WRITE, OP_ALLOC, OP_FREE = 0, 1, 2, 3


def heap_of_slot(cfg: PoolConfig, slot: jax.Array) -> jax.Array:
    """Region id a physical slot belongs to (static boundaries)."""
    return fl.region_of_slot(cfg, slot).astype(jnp.uint32)


def apply_op(cfg: PoolConfig, state: Dict, op, obj_ids: jax.Array,
             values: jax.Array) -> Tuple[Dict, jax.Array]:
    """All four pool ops as ONE op-code-parameterized transition.
    `op` may be a TRACED scalar (the engine's batched traces) or a python
    constant (the per-op wrappers below — XLA folds the masks and
    recovers each op's minimal program). Returns (state, read_vals [k,W];
    zeros for non-read ops and dead/padding lanes).

    Why not `lax.switch` over four per-op branches: branches that update
    different subsets of the state pytree break XLA's in-place aliasing
    of the surrounding scan carry, which silently re-copies the heap
    (`data`, O(n_slots)) EVERY step. As a single branch-free program,
    every update is a K-sized scatter on the same buffers — masked-off
    lanes route to drop indices — so per-op cost is O(K) in the batch
    size and independent of pool size (docs/allocator.md).

    Op semantics (ids < 0 are padding everywhere):
      read   gather payloads; access bit + ATC-when-armed; COLD-hit
             promotion count; fault-in HOST superblocks
      write  scatter payloads to live ids (a store is also an access)
      alloc  claim a slot per dead id — NEW heap first, spilling COLD
             then HOT off the carried free rings (`freelist.pop`); live
             ids are re-written in place (update semantics); a
             duplicated id claims ONE slot (first occurrence wins)
      free   release live ids: slot pushed on its region's free ring
             (tail; dense-first order returns at the next restock),
             occupancy -1; duplicates in one batch free once"""
    op = jnp.asarray(op, jnp.int32)
    is_read, is_write = op == OP_READ, op == OP_WRITE
    is_alloc, is_free = op == OP_ALLOC, op == OP_FREE

    valid = obj_ids >= 0
    ids = jnp.maximum(obj_ids, 0)
    words = state["table"][ids]
    live = ot.is_live(words) & valid
    first = fl.first_occurrence(obj_ids)
    slots = ot.slot_of(words).astype(jnp.int32)

    # Ordering rule for every carried buffer below: SCATTER BEFORE
    # GATHER. A gather followed by a scatter on the same scan-carried
    # array makes XLA's copy-insertion preserve the pre-scatter view by
    # copying the whole buffer every step (O(n_slots) for `data`);
    # scatter-then-gather aliases in place. Each op kind uses only one
    # side (reads never scatter data, allocs/frees never gather it), so
    # the reordering is semantically free.

    # --- free: push released slots (mask is empty otherwise) ---
    f_mask = is_free & live & first
    free_q, free_head, free_count = fl.push(
        cfg, state["free_q"], state["free_head"], state["free_count"],
        slots, f_mask)

    # --- alloc: pop fresh slots off the rings (need is empty otherwise;
    # an op is either alloc or free, so push/pop order is immaterial) ---
    need = is_alloc & (~live) & valid & first
    new_slot, ok_new, free_head, free_count = fl.pop(
        cfg, free_q, free_head, free_count, need)
    a_do = (is_alloc & live) | ok_new        # lanes an alloc writes
    a_slot = jnp.where(ok_new, new_slot, slots)

    # --- data: one scatter serves write + alloc (dead/padding lanes
    # route to the scratch row and must write ZEROS — its invariant) ---
    d_mask = (is_write & live) | a_do
    d_slot = jnp.where(is_alloc, a_slot, slots)
    data = state["data"].at[jnp.where(d_mask, d_slot, cfg.n_slots)].set(
        jnp.where(d_mask[:, None], values.astype(state["data"].dtype), 0),
        mode="drop")

    # --- read output: gathered AFTER the (empty-on-read) scatter ---
    vals = jnp.where((is_read & live)[:, None], data[slots], 0)

    # --- table: dereference access bits (+ATC when armed), alloc words,
    # free words. The alloc/free rewrites go through fresh K-scattered
    # mask/value arrays + an elementwise select (same no-gather-then-
    # scatter rule; record_access does likewise internally) ---
    rw_live = (is_read | is_write) & live
    tbl = ot.record_access(state["table"],
                           jnp.where(rw_live, obj_ids, -1),
                           armed=state["armed"])
    alloc_words = jnp.where(
        ok_new, ot.pack(a_slot.astype(jnp.uint32),
                        heap_of_slot(cfg, a_slot), access=1),
        # alloc of a live id: in-place update, set the access bit
        words | (ot.ACCESS_MASK << ot.ACCESS_SHIFT))
    a_dst = jnp.where(a_do, ids, cfg.max_objects)
    hit_a = jnp.zeros((cfg.max_objects,), jnp.bool_).at[a_dst].set(
        True, mode="drop")
    word_a = jnp.zeros((cfg.max_objects,), jnp.uint32).at[a_dst].set(
        alloc_words, mode="drop")
    hit_f = jnp.zeros((cfg.max_objects,), jnp.bool_).at[
        jnp.where(f_mask, ids, cfg.max_objects)].set(True, mode="drop")
    tbl = jnp.where(hit_f, ot.free_word(),
                    jnp.where(hit_a, word_a, tbl))

    # --- slot ownership + carried occupancy/referenced ---
    owner = state["slot_owner"] \
        .at[jnp.where(ok_new, a_slot, cfg.n_slots)].set(
            jnp.where(ok_new, obj_ids, -1), mode="drop") \
        .at[jnp.where(f_mask, slots, cfg.n_slots)].set(-1, mode="drop")
    sb_occ = state["sb_occ"] \
        .at[jnp.where(ok_new, a_slot // cfg.sb_slots, cfg.n_sbs)].add(
            1, mode="drop") \
        .at[jnp.where(f_mask, slots // cfg.sb_slots, cfg.n_sbs)].add(
            -1, mode="drop")
    touch = rw_live | a_do
    slot_ref = state["slot_ref"] \
        .at[jnp.where(touch, jnp.where(is_alloc, a_slot, slots),
                      cfg.n_slots)].set(True, mode="drop") \
        .at[jnp.where(f_mask, slots, cfg.n_slots)].set(False, mode="drop")

    # --- fault accounting (reads fault HOST superblocks back in) ---
    sbs = slots // cfg.sb_slots
    on_host = is_read & live & (state["sb_tier"][sbs] == HOST)
    fault_mask = jnp.zeros((cfg.n_sbs,), jnp.bool_).at[
        jnp.where(on_host, sbs, cfg.n_sbs)].set(True, mode="drop")
    n_faults = jnp.sum(fault_mask).astype(jnp.int32)
    sb_tier = jnp.where(fault_mask, HBM, state["sb_tier"]).astype(jnp.int8)
    sb_evict = jnp.where(fault_mask, NORMAL,
                         state["sb_evict"]).astype(jnp.int8)

    # --- window counters (free ticks no counters; the op clock lives in
    # the engine) ---
    accs = jnp.sum(rw_live) + jnp.sum(a_do)
    promos = jnp.sum(rw_live & (ot.heap_of(words) == ot.COLD)
                     ).astype(jnp.int32)
    state = dict(state, data=data, table=tbl, slot_owner=owner,
                 free_q=free_q, free_head=free_head,
                 free_count=free_count, sb_occ=sb_occ, slot_ref=slot_ref,
                 sb_tier=sb_tier, sb_evict=sb_evict,
                 win_accesses=state["win_accesses"] + accs,
                 win_promos=state["win_promos"] + promos,
                 win_faults=state["win_faults"] + n_faults,
                 total_faults=state["total_faults"] + n_faults)
    return state, vals


def _zero_values(cfg: PoolConfig, obj_ids: jax.Array) -> jax.Array:
    return jnp.zeros((obj_ids.shape[0], cfg.slot_words),
                     jnp.dtype(cfg.dtype))


def alloc(cfg: PoolConfig, state: Dict, obj_ids: jax.Array,
          values: jax.Array) -> Dict:
    """Allocate `obj_ids` [k] (see `apply_op`: NEW->COLD->HOT spill off
    the carried rings, O(k), first-occurrence-wins on duplicates)."""
    state, _ = apply_op(cfg, state, OP_ALLOC, obj_ids, values)
    return state


def read(cfg: PoolConfig, state: Dict, obj_ids: jax.Array
         ) -> Tuple[jax.Array, Dict]:
    """Gather object payloads for `obj_ids` [k] (−1 entries return zeros).
    This is the paper's pointer dereference — see `apply_op`."""
    state, vals = apply_op(cfg, state, OP_READ, obj_ids,
                           _zero_values(cfg, obj_ids))
    return vals, state


def write(cfg: PoolConfig, state: Dict, obj_ids: jax.Array,
          values: jax.Array) -> Dict:
    """Scatter payloads to live objects (a store is also an access)."""
    state, _ = apply_op(cfg, state, OP_WRITE, obj_ids, values)
    return state


def free(cfg: PoolConfig, state: Dict, obj_ids: jax.Array) -> Dict:
    """Release objects (slot returns to its region's free ring) — see
    `apply_op`."""
    state, _ = apply_op(cfg, state, OP_FREE, obj_ids,
                        _zero_values(cfg, obj_ids))
    return state


# ---------------------------------------------------------------------------
# Superblock summaries (the ONLY view backends get — object-oblivious)
# ---------------------------------------------------------------------------
def sb_occupancy(cfg: PoolConfig, state: Dict) -> jax.Array:
    """Per-superblock live-slot count [n_sbs] — the CARRIED `sb_occ`
    counters (alloc +1 / free -1 / migrate +-1), an O(n_sbs) read with no
    scatter-add over all slots. `recompute_sb_occupancy` is the O(n_slots)
    oracle (tests assert the carry never drifts)."""
    return state["sb_occ"]


def recompute_sb_occupancy(cfg: PoolConfig,
                           slot_owner: jax.Array) -> jax.Array:
    """O(n_slots) occupancy from the slot-owner array — the consistency
    oracle for the carried counters, and the rebuild used by maintenance
    passes that rewrite whole regions (`collector.compact_heap`)."""
    live_slot = slot_owner >= 0
    sb_of_slot = jnp.arange(cfg.n_slots) // cfg.sb_slots
    return jnp.zeros((cfg.n_sbs,), jnp.int32).at[sb_of_slot].add(
        live_slot.astype(jnp.int32))


def superblock_stats(cfg: PoolConfig, state: Dict) -> Dict[str, jax.Array]:
    """Per-superblock: occupancy, referenced (any access bit within),
    region id, tier, evict state. This is the page-table-level view the
    paper's unmodified backends consume. Both expensive columns are
    carried (occupancy counters + per-slot referenced bits), so the view
    is O(n_sbs) reads + one elementwise reshape — no per-window
    gather/scatter over all slots."""
    ref = state["slot_ref"].reshape(cfg.n_sbs, cfg.sb_slots).any(axis=1)
    return {"occupancy": sb_occupancy(cfg, state), "referenced": ref,
            "region": cfg.sb_region_ids(),
            "tier": state["sb_tier"], "evict": state["sb_evict"]}


def rss_bytes(cfg: PoolConfig, state: Dict) -> jax.Array:
    """Resident (HBM-tier) bytes: occupied superblocks still in HBM."""
    occ = sb_occupancy(cfg, state)
    resident = (occ > 0) & (state["sb_tier"] == HBM)
    return jnp.sum(resident).astype(jnp.float32) * float(cfg.sb_bytes)


def host_bytes(cfg: PoolConfig, state: Dict) -> jax.Array:
    occ = sb_occupancy(cfg, state)
    out = (occ > 0) & (state["sb_tier"] == HOST)
    return jnp.sum(out).astype(jnp.float32) * float(cfg.sb_bytes)
