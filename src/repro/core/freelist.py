"""Carried free-slot queues — the pool's O(K)-per-op allocator state.

The paper's 3%-overhead claim requires allocator work proportional to the
*accesses*, not the *heap*. The original `pool.alloc` recomputed a dense
free-slot cumsum over all `n_slots` on every op; this module replaces it
with free-list state carried in the pool pytree (HADES's own allocator is
an O(1) bump/free-list per op — this is its fixed-shape array analog):

    free_q     int32 [n_slots]  three per-region circular rings; region r's
                                ring lives in free_q[lo_r:hi_r] (its own
                                slot span, so spans never collide)
    free_head  int32 [3]        ring head per region, indexed by heap id
                                (NEW=0, HOT=1, COLD=2)
    free_count int32 [3]        free slots available per region

Each ring is a FIFO: `pop` takes from the head (the *lowest* free slots as
of the last restock — the dense-first bias), `push` appends freed slots at
the tail. Between collects every alloc/free is O(K) in the batch size:
K gathers/scatters into the rings plus O(K^2) in-batch dedup (K is the op
batch width, never the pool size). Once per window the collector —
which already sweeps the heap — calls `restock`, rebuilding every ring in
ascending slot order from `slot_owner`, so the HOT-compactness bias holds
at window granularity rather than per op.

Invariant (checked by tests/test_pool_collector.py): at every op boundary
the multiset of ring entries in [head, head+count) per region equals the
free (`slot_owner == -1`) slots of that region; entries outside the live
window are dead and deterministically zeroed at each restock.

Allocation spill order is NEW -> COLD -> HOT (a real allocator never
fails while the pool has space; fresh objects prefer NEW, then the
reclaim-target region, and displace the dense HOT region last).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import object_table as ot

# heap-id order for the [3]-indexed carries
_REGIONS = (ot.NEW, ot.HOT, ot.COLD)
# allocation spill order (matches the pre-freelist `_alloc_order`)
_SPILL = (ot.NEW, ot.COLD, ot.HOT)


def _spans(cfg, order) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, cap) int32 [3] arrays for the given region order."""
    lo = jnp.asarray([cfg.region(r)[0] for r in order], jnp.int32)
    cap = jnp.asarray([cfg.region(r)[1] - cfg.region(r)[0] for r in order],
                      jnp.int32)
    return lo, cap


def region_of_slot(cfg, slot: jax.Array) -> jax.Array:
    """Heap-region id of a physical slot (static boundaries), int32."""
    new_end = cfg.region(ot.NEW)[1]
    hot_end = cfg.region(ot.HOT)[1]
    return jnp.where(slot < new_end, ot.NEW,
                     jnp.where(slot < hot_end, ot.HOT, ot.COLD)
                     ).astype(jnp.int32)


def first_occurrence(ids: jax.Array) -> jax.Array:
    """[k] bool: True where the entry is the first occurrence of its id.
    Duplicate ids in one batch must not pop/push a ring twice (a
    double-pushed slot would later be handed to two different objects).
    O(K log K): stable argsort + adjacent compare + inverse scatter — a
    pairwise K x K matrix would go quadratic on bulk-load batches (the
    bench's initial alloc passes K in the thousands)."""
    k = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    s = ids[order]
    head = jnp.concatenate([jnp.ones((1,), jnp.bool_), s[1:] != s[:-1]])
    return jnp.zeros((k,), jnp.bool_).at[order].set(head)


def seed(cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fresh rings for an empty pool: every region's ring is its own slot
    span in ascending order (== arange over the whole pool), all free."""
    free_q = jnp.arange(cfg.n_slots, dtype=jnp.int32)
    head = jnp.zeros((3,), jnp.int32)
    _, cap = _spans(cfg, _REGIONS)
    return free_q, head, cap


def pop(cfg, free_q: jax.Array, head: jax.Array, count: jax.Array,
        need: jax.Array
        ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Pop one free slot per True entry of `need` [k], NEW spilling to
    COLD then HOT. Returns (slots [k], ok [k], head', count'); entries
    with ok=False found the pool full and popped nothing. O(K): a rank
    cumsum over the batch plus K gathers — no sweep over n_slots."""
    lo, cap = _spans(cfg, _SPILL)
    sidx = jnp.asarray(_SPILL, jnp.int32)
    cnt = count[sidx]                       # spill-ordered counts [3]
    hd = head[sidx]
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt)])

    rank = jnp.cumsum(need.astype(jnp.int32)) - 1      # [k]
    ok = need & (rank < cum[3])
    # spill level by cumulative availability (0=NEW, 1=COLD, 2=HOT)
    sel = (rank >= cum[1]).astype(jnp.int32) + \
        (rank >= cum[2]).astype(jnp.int32)
    pos = (hd[sel] + rank - cum[sel]) % cap[sel]
    slots = free_q[jnp.clip(lo[sel] + pos, 0, cfg.n_slots - 1)]

    total = jnp.sum(need.astype(jnp.int32))
    take = jnp.clip(total - cum[:3], 0, cnt)           # per level [3]
    head = head.at[sidx].set((hd + take) % cap)
    count = count.at[sidx].set(cnt - take)
    return slots, ok, head, count


def pop_region(cfg, free_q: jax.Array, head: jax.Array, count: jax.Array,
               region: int, need: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Pop one free slot per True entry of `need` [m] from ONE region's
    ring (no spill) — the collector's destination-slot source (dense-first
    as of the last restock, O(m)). Returns (slots, ok, head', count')."""
    lo_, hi_ = cfg.region(region)
    cap_ = hi_ - lo_
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    ok = need & (rank < count[region])
    pos = (head[region] + rank) % cap_
    slots = free_q[jnp.clip(lo_ + pos, 0, cfg.n_slots - 1)]
    take = jnp.minimum(jnp.sum(need.astype(jnp.int32)), count[region])
    head = head.at[region].set((head[region] + take) % cap_)
    count = count.at[region].add(-take)
    return slots, ok, head, count


def push(cfg, free_q: jax.Array, head: jax.Array, count: jax.Array,
         slots: jax.Array, mask: jax.Array
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Append `slots[mask]` to their regions' ring tails. O(K): per-item
    region ranks over the batch plus one K-scatter into the rings."""
    lo, cap = _spans(cfg, _REGIONS)
    reg = region_of_slot(cfg, slots)                   # [k] heap ids
    rank = jnp.zeros_like(slots)
    add = []
    for r in range(3):
        m = mask & (reg == r)
        rank = jnp.where(m, jnp.cumsum(m.astype(jnp.int32)) - 1, rank)
        add.append(jnp.sum(m.astype(jnp.int32)))
    pos = (head[reg] + count[reg] + rank) % cap[reg]
    idx = jnp.where(mask, lo[reg] + pos, cfg.n_slots)  # masked -> dropped
    free_q = free_q.at[idx].set(slots, mode="drop")
    return free_q, head, count + jnp.stack(add)


def restock(cfg, free_q: jax.Array, slot_owner: jax.Array
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rebuild every ring from `slot_owner` in ascending slot order —
    the once-per-window O(n_slots) sweep that restores the dense-first
    bias (the collector already sweeps the heap each collect; this rides
    that budget). Implemented as one SORT per region (a few hundred µs
    for tens of thousands of slots) rather than a scatter (~4x slower on
    CPU for the same size). Dead ring entries are zeroed so the carried
    state is a pure function of the owner array (bit-parity across
    paths)."""
    heads = jnp.zeros((3,), jnp.int32)
    counts = []
    for r in _REGIONS:
        lo_, hi_ = cfg.region(r)
        cap_ = hi_ - lo_
        seg_free = slot_owner[lo_:hi_] == -1
        n_free = jnp.sum(seg_free.astype(jnp.int32))
        # free slots sort to the front in ascending order; occupied ones
        # sort to the back as INT32_MAX sentinels and are then zeroed
        keys = jnp.where(seg_free, jnp.arange(lo_, hi_, dtype=jnp.int32),
                         jnp.iinfo(jnp.int32).max)
        ring = jnp.sort(keys)
        ring = jnp.where(jnp.arange(cap_) < n_free, ring, 0)
        free_q = free_q.at[lo_:hi_].set(ring)
        counts.append(n_free)
    return free_q, heads, jnp.stack(counts)
