"""Fused window-execution engine — one device dispatch per serving window.

The paper's 3%-overhead claim is about *tracking* cost, not dispatch cost;
a frontend that pays a host round-trip per op (separately jitted
read/write/alloc with a Python tick in between) measures the wrong thing.
This engine executes an entire serving window — `collect_every` batched
ops, the Object Collector pass, MIAD, MADV_COLD candidate marking, and the
backend step — as ONE `jax.jit`-compiled `lax.scan`:

    trace:  {"op": [T], "ids": [T, K], "values": [T, K, W]}
      |                       (K ops per step, ids < 0 are padding)
      v
    lax.scan over T steps:
        lax.switch(op)  -> pool.read / write / alloc / free
        step clock +1
        lax.cond(step % every == every-1 & overlap) -> arm ATC window
        lax.cond(step % every == 0) -> collect + backend  (fused)
      |
      v
    (state', read outputs [T, K, W], per-step reports)

Nothing inside a window may sync to the host; the per-step report pytree
has a fixed shape (zeros on non-collect steps, `did_collect` marks the
real ones) so callers pull results *after* the window. The `Hades`
frontend wrapper (core/frontend.py) rides the same machinery one step at
a time via `apply_step`, so the step-by-step and fused paths are
bit-identical (tests/test_engine.py asserts it).

Every op in a trace advances the window clock — including `free` (the
clock counts ops, not accesses; a data-dependent clock would not scan).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.core import collector as col
from repro.core import pool as pl

# op codes for batched traces (defined by the pool's unified op)
READ, WRITE = pl.OP_READ, pl.OP_WRITE
ALLOC, FREE = pl.OP_ALLOC, pl.OP_FREE
OP_CODES = {"read": READ, "write": WRITE, "alloc": ALLOC, "free": FREE}


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Static window/collector/backend configuration (hashable; closed
    over by the jitted window program). Field-compatible with the old
    `HadesOptions` — frontend.py aliases it."""
    collect_every: int = 8
    # a backend.Backend (from backend.make), a deprecated BackendConfig,
    # or a registered name — normalized via backend.as_backend
    backend: Union[be.Backend, be.BackendConfig, str] = dataclasses.field(
        default_factory=lambda: be.make("reactive"))
    collector: col.CollectorConfig = dataclasses.field(
        default_factory=col.CollectorConfig)
    enabled: bool = True           # False = allocator-only (no tidying)
    # Arm ATC tracking for the window preceding each collect. The paper's
    # scope guards decrement on function EXIT; in a synchronous loop every
    # step has exited before the collector runs, so nothing is in flight
    # and arming would only veto migrations spuriously. Set True when the
    # runtime overlaps step dispatch with collection (async serving) —
    # then ATC>0 marks objects a concurrent step may still dereference.
    overlap_collect: bool = False


def zero_report() -> Dict[str, jax.Array]:
    """The no-collect report: same pytree structure/dtypes as a real one
    so `lax.cond` branches agree."""
    i32 = functools.partial(jnp.zeros, (), jnp.int32)
    f32 = functools.partial(jnp.zeros, (), jnp.float32)
    report = {
        "moved_to_hot": i32(), "moved_to_cold": i32(),
        "skipped_atc": i32(),
        "promotion_rate": f32(),
        "proactive_ok": jnp.zeros((), jnp.bool_),
        "ciw_threshold": f32(),
        "win_accesses": i32(), "win_faults": i32(),
        "rss_bytes": f32(), "host_bytes": f32(),
        "did_collect": jnp.zeros((), jnp.bool_),
    }
    report.update(be.zero_telemetry())
    return report


def collect_and_backend(pool_cfg: pl.PoolConfig, col_cfg: col.CollectorConfig,
                        backend: be.Backend, state: Dict
                        ) -> Tuple[Dict, Dict[str, jax.Array]]:
    """Collector pass + backend step as one fused transition. The backend
    sees the closing window's superblock stats (pre-clear), exactly as the
    old two-dispatch Hades.collect did, plus its own carried state
    (`state["bstate"]`, threaded through the scan carry so stateful
    backends run inside the single-dispatch window); RSS/host byte gauges
    are computed on-device so callers never sync mid-window."""
    state, report = col.collect(pool_cfg, col_cfg, state)
    stats = report.pop("sb_stats")
    signals = {"proactive_ok": report["proactive_ok"],
               "epoch": state["epoch"]}
    bstate, tier, evict, telemetry = backend.step(
        pool_cfg, state["bstate"], stats, state["sb_tier"],
        state["sb_evict"], signals)
    state = dict(state, bstate=bstate, sb_tier=tier, sb_evict=evict)
    report.update(telemetry)
    occupied = stats["occupancy"] > 0
    sb_bytes = float(pool_cfg.sb_bytes)
    report["rss_bytes"] = jnp.sum(
        occupied & (tier == pl.HBM)).astype(jnp.float32) * sb_bytes
    report["host_bytes"] = jnp.sum(
        occupied & (tier == pl.HOST)).astype(jnp.float32) * sb_bytes
    report["did_collect"] = jnp.ones((), jnp.bool_)
    return state, report


# ---------------------------------------------------------------------------
# single step — the Hades wrapper's path (op/collect decisions are static:
# the host knows the deterministic window clock, so no device cond needed)
# ---------------------------------------------------------------------------
def apply_step(pool_cfg: pl.PoolConfig, col_cfg: col.CollectorConfig,
               backend: be.Backend, state: Dict, ids: jax.Array,
               values: Optional[jax.Array], *, op: str,
               do_arm: bool = False, do_collect: bool = False
               ) -> Tuple[Dict, Optional[jax.Array], Dict[str, jax.Array]]:
    """One op + its share of the window protocol, fused into a single
    compiled program: apply `op`, then (statically) arm and/or run
    collect+backend. Returns (state, read_values_or_None, report)."""
    out = None
    if op == "read":
        out, state = pl.read(pool_cfg, state, ids)
    elif op == "write":
        state = pl.write(pool_cfg, state, ids, values)
    elif op == "alloc":
        state = pl.alloc(pool_cfg, state, ids, values)
    elif op == "free":
        state = pl.free(pool_cfg, state, ids)
    else:
        raise ValueError(op)
    if do_arm:
        state = col.arm(state)
    if do_collect:
        state, report = collect_and_backend(pool_cfg, col_cfg, backend,
                                            state)
    else:
        report = zero_report()
    return state, out, report


# ---------------------------------------------------------------------------
# the window protocol over an ARBITRARY per-step transition
# ---------------------------------------------------------------------------
def window_program(step_fn, collect_fn, arm_fn, *, every: int,
                   enabled: bool = True, overlap: bool = False,
                   zero_report_fn=zero_report, pre_fn=None):
    """Build the two fused-window program shapes over an arbitrary
    per-step transition — the machinery behind `make_run_window`, reused
    by the server's scanned decode windows (runtime/server.py):

        step_fn(state, xs)  -> (state, out_pytree)     one window step
        collect_fn(state)   -> (state, report)         fused collect+backend
        arm_fn(state)       -> state                   ATC arming (epoch)
        pre_fn(state, exs)  -> state                   window-ENTRY events

    Returns (run_generic(state, xs, step0), run_aligned(state, xs)), both
    UNJITTED so callers can close extra operands (e.g. model params) over
    `step_fn` and jit at their own boundary. Window semantics are the
    engine contract: the clock ticks once per step; arm fires after the
    step at clock % every == every-1 (overlap only); collect+backend runs
    after the step at clock % every == 0. `run_aligned` requires
    T % every == 0 and step0 % every == 0 and is cond-free (one collect
    per window, statically placed); `run_generic` handles any T/step0
    with a cond-gated collect. Reports come back per-STEP in both shapes
    (zeros off window closers; `did_collect` marks real ones).

    `pre_fn` is the lane-event plumbing for continuous batching
    (docs/serving.md): when given, both runners take an extra per-step
    event pytree `exs` (leading axis T, like xs) and apply
    `pre_fn(state, exs[t])` BEFORE the step at every window-ENTRY clock
    (step % every == 0) — the serving contract that lane events
    (free / admit / re-parameterize) resolve at window boundaries,
    inside the same single dispatch. Event slices at non-entry steps are
    ignored. The aligned shape applies pre_fn statically at each
    window's first step; the generic shape gates it on a per-step
    `lax.cond`, which breaks XLA's in-place carry aliasing on CPU
    (docs/allocator.md) — it remains the semantics reference; drive
    event windows through the aligned shape."""
    every = int(every)

    # -- generic shape: per-step cond ---------------------------------------
    def step_body(carry, xs):
        state, step = carry
        if pre_fn is not None:
            xs, exs = xs
            state = jax.lax.cond(step % every == 0,
                                 lambda s: pre_fn(s, exs),
                                 lambda s: s, state)
        state, out = step_fn(state, xs)
        step = step + 1
        if enabled:
            if overlap:
                state = jax.lax.cond(step % every == every - 1,
                                     arm_fn, lambda s: s, state)
            state, report = jax.lax.cond(
                step % every == 0, collect_fn,
                lambda s: (s, zero_report_fn()), state)
        else:
            report = zero_report_fn()
        return (state, step), {"out": out, "report": report}

    def run_generic(state, xs, step0, exs=None):
        step0 = jnp.asarray(step0, jnp.int32)
        if pre_fn is not None:
            xs = (xs, exs)
        (state, _), ys = jax.lax.scan(step_body, (state, step0), xs)
        return state, ys["out"], ys["report"]

    # -- window-aligned shape: cond-free ------------------------------------
    def window_body(state, wxs):
        if pre_fn is not None:
            wxs, wexs = wxs
            state = pre_fn(state, jax.tree.map(lambda v: v[0], wexs))
        if every > 1:
            head = jax.tree.map(lambda v: v[:every - 1], wxs)
            state, outs = jax.lax.scan(step_fn, state, head)
            # arm fires AFTER step every-1 (the generic path's
            # step % every == every-1 check runs post-step)
            if enabled and overlap:
                state = arm_fn(state)
        last = jax.tree.map(lambda v: v[every - 1], wxs)
        state, out_last = step_fn(state, last)
        if every == 1 and enabled and overlap:
            # degenerate cadence: every step is both the arming and the
            # closing step, and the generic path arms post-step
            state = arm_fn(state)
        if enabled:
            state, report = collect_fn(state)
        else:
            report = zero_report_fn()
        if every > 1:
            outs = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                outs, out_last)
        else:
            outs = jax.tree.map(lambda b: b[None], out_last)
        return state, {"out": outs, "report": report}

    def run_aligned(state, xs, exs=None):
        t = jax.tree.leaves(xs)[0].shape[0]

        def to_windows(tree):
            return jax.tree.map(
                lambda v: v.reshape((t // every, every) + v.shape[1:]),
                tree)
        wxs = to_windows(xs)
        if pre_fn is not None:
            wxs = (wxs, to_windows(exs))
        state, ys = jax.lax.scan(window_body, state, wxs)
        outs = jax.tree.map(lambda v: v.reshape((t,) + v.shape[2:]),
                            ys["out"])
        # scatter the per-window reports into the per-step layout the
        # generic shape produces (zeros except at window closers)
        reports = jax.tree.map(
            lambda z, w: jnp.broadcast_to(
                z, (t,) + z.shape).at[every - 1::every].set(w),
            zero_report_fn(), ys["report"])
        return state, outs, reports

    return run_generic, run_aligned


# ---------------------------------------------------------------------------
# fused window — the whole access->collect->backend loop in one dispatch
# ---------------------------------------------------------------------------
def _op_step(pool_cfg: pl.PoolConfig, state: Dict, xs: Dict
             ) -> Tuple[Dict, jax.Array]:
    """Apply one traced op batch (the scan body's op dispatch).

    This is `pool.apply_op` with the TRACED op code — one branch-free
    program per step, not a `lax.switch` over four per-op branches: XLA
    cannot alias a scan carry in place through a conditional whose
    branches update different buffers, so a switch silently re-copied
    the whole heap (`data`) every step, making per-op cost O(n_slots).
    The mask-parameterized op keeps it O(K)."""
    state, vals = pl.apply_op(pool_cfg, state, xs["op"], xs["ids"],
                              xs["values"])
    return state, vals.astype(xs["values"].dtype)


def make_run_window(pool_cfg: pl.PoolConfig, opts: EngineOptions):
    """Build the jitted window programs. The returned
    run(state, trace, step0) -> (state, outs [T,K,W], reports {[T]...})
    dispatches ONE device program for the whole trace.

    Two compiled shapes exist behind the same signature:

      * window-aligned (T % collect_every == 0 and step0 % collect_every
        == 0, the production case): an outer scan over whole windows —
        inner cond-FREE scan over the first every-1 ops, then statically
        arm (if overlapping), apply the window-closing op, and run
        collect+backend. No `lax.cond` anywhere (a per-step cond costs
        real time on CPU), collect work appears once per window.
      * generic (any T/step0): per-step scan with a cond-gated collect —
        the semantics reference for arbitrary clock offsets.

    Reports always come back per-STEP (zeros on non-collect steps,
    `did_collect` marks window closers) so both shapes look identical to
    callers; `step0` is the op-clock value BEFORE the trace, keeping the
    cadence aligned across successive calls."""
    col_cfg = opts.collector
    backend = be.as_backend(opts.backend)
    every = int(opts.collect_every)
    cab = functools.partial(collect_and_backend, pool_cfg, col_cfg, backend)
    run_generic, run_aligned = window_program(
        functools.partial(_op_step, pool_cfg), cab, col.arm,
        every=every, enabled=opts.enabled, overlap=opts.overlap_collect)

    # donate the pool state: the window updates it in place instead of
    # double-buffering the whole pool (notably `data`,
    # (n_slots+1) x slot_words) on every dispatch. Callers must treat the
    # state they pass in as CONSUMED — reuse raises a deleted-buffer
    # error (tests/test_donation.py)
    jit_generic = jax.jit(run_generic, donate_argnums=(0,))
    jit_aligned = jax.jit(run_aligned, donate_argnums=(0,))

    def run(state, trace, step0=0):
        t = int(trace["op"].shape[0])
        if (isinstance(step0, int) and step0 % every == 0
                and t % every == 0 and t > 0):
            return jit_aligned(state, trace)
        return jit_generic(state, trace, step0)

    return run


def make_trace(pool_cfg: pl.PoolConfig,
               steps: Sequence[Tuple[str, jax.Array, Optional[jax.Array]]],
               *, k: Optional[int] = None) -> Dict[str, jax.Array]:
    """Pack a Python list of (op, ids, values_or_None) into the stacked
    fixed-shape trace `run_window` scans over. Each step's ids are padded
    to `k` with -1 (all pool ops drop negative ids); values are padded
    with zeros and cast to the pool dtype."""
    import numpy as np
    if k is None:
        k = max([1] + [len(np.atleast_1d(ids)) for _, ids, _ in steps])
    w = pool_cfg.slot_words
    dtype = jnp.dtype(pool_cfg.dtype)
    t = len(steps)
    op_a = np.zeros((t,), np.int32)
    ids_a = np.full((t, k), -1, np.int32)
    val_a = np.zeros((t, k, w), dtype)
    for i, (op, ids, values) in enumerate(steps):
        op_a[i] = OP_CODES[op]
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        assert len(ids) <= k, f"step {i}: {len(ids)} ops > k={k}"
        ids_a[i, :len(ids)] = ids
        if values is not None:
            val_a[i, :len(ids)] = np.asarray(values, dtype).reshape(-1, w)
    return {"op": jnp.asarray(op_a), "ids": jnp.asarray(ids_a),
            "values": jnp.asarray(val_a)}


def window_reports(reports: Dict[str, jax.Array]) -> List[Dict[str, float]]:
    """Host-side extraction of the real collect reports from a window's
    stacked per-step report pytree (the only place a sync happens)."""
    import numpy as np
    host = {kk: np.asarray(v) for kk, v in reports.items()}
    out = []
    for i in np.nonzero(host["did_collect"])[0]:
        out.append({kk: float(v[i]) for kk, v in host.items()})
    return out


class Engine:
    """Holds the compiled entry points for one pool geometry + options.

    `run_window` / `serve_steps` are the production path (one dispatch per
    window); `step` is the per-op compatibility path the `Hades` wrapper
    uses (one dispatch per op, collect fused into the op that closes the
    window)."""

    def __init__(self, pool_cfg: pl.PoolConfig,
                 opts: Optional[EngineOptions] = None):
        self.cfg = pool_cfg
        self.opts = opts or EngineOptions()
        self.backend = be.as_backend(self.opts.backend)
        self._run = make_run_window(pool_cfg, self.opts)
        # every entry point donates the incoming pool state (in-place
        # window updates; see make_run_window)
        self._apply = jax.jit(
            functools.partial(apply_step, pool_cfg, self.opts.collector,
                              self.backend),
            static_argnames=("op", "do_arm", "do_collect"),
            donate_argnums=(0,))
        self._collect = jax.jit(functools.partial(
            collect_and_backend, pool_cfg, self.opts.collector,
            self.backend), donate_argnums=(0,))

    def init(self) -> Dict:
        """Fresh pool state, with the backend's carried state seeded in
        (`bstate` rides the window-scan carry from here on)."""
        return dict(pl.init(self.cfg), bstate=self.backend.init(self.cfg))

    # -- fused path ---------------------------------------------------------
    def run_window(self, state: Dict, trace: Dict[str, jax.Array],
                   step0: int = 0):
        """Execute `trace` (any number of steps/windows) as ONE dispatch.
        `state` is DONATED: the pool updates in place and the passed-in
        pytree must not be used again (keep the returned state)."""
        return self._run(state, trace, step0)

    def serve_steps(self, state: Dict, trace: Dict[str, jax.Array],
                    *, step0: int = 0, window: Optional[int] = None):
        """Stream `trace` window-by-window (`window` steps per dispatch,
        default `collect_every`) so reports can be consumed between
        dispatches. Returns (state, outs [T,K,W], reports list). The
        incoming `state` is donated to the first window's dispatch and
        each window's output state is donated to the next — the pool is
        never double-buffered across the stream."""
        t = trace["op"].shape[0]
        window = window or self.opts.collect_every
        outs, reps = [], []
        for lo in range(0, t, window):
            chunk = {kk: v[lo:lo + window] for kk, v in trace.items()}
            state, out, rep = self._run(state, chunk, step0 + lo)
            outs.append(out)
            reps.extend(window_reports(rep))
        if not outs:               # empty trace: clean no-op
            return state, jnp.zeros_like(trace["values"]), reps
        return state, jnp.concatenate(outs, axis=0), reps

    # -- per-op compatibility path ------------------------------------------
    def step(self, state: Dict, op: str, ids, values=None, *,
             do_arm: bool = False, do_collect: bool = False):
        ids = jnp.asarray(ids, jnp.int32)
        if values is not None:
            values = jnp.asarray(values)
        return self._apply(state, ids, values, op=op, do_arm=do_arm,
                           do_collect=do_collect)

    def collect_now(self, state: Dict):
        return self._collect(state)
