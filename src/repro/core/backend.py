"""Page-level reclamation backends (paper §3.3 / §5.2) — a pluggable,
registry-based, STATEFUL protocol.

Backends are *object-oblivious by construction*: their only inputs are
per-superblock summaries (occupancy, referenced bit, region id, tier,
evict state) — the same information the kernel's page reclaim has (PTE
accessed bits + LRU lists) — plus their own carried state. They never see
the object table. This enforces the paper's decoupling: the frontend
engineers the address space; an unmodified backend acts on pages.

The protocol (one implementation shared by the jit Engine AND the numpy
SimHeap via `simheap`'s page adapter — one oracle):

    backend = make(name, **params)          # unknown names rejected HERE
    bstate  = backend.init(geom)            # pytree of arrays (may be {})
    bstate, tier, evict, telemetry = backend.step(
        geom, bstate, stats, tier, evict, signals)

  * `geom` is page geometry only: anything exposing `.n_sbs` and
    `.sb_bytes` (`pool.PoolConfig` in production, `PageGeometry` for the
    byte-granular simulator where a "superblock" is a 4 KiB page).
  * `bstate` is carried across windows by the CALLER — inside the
    Engine's fused `lax.scan` it lives in the pool-state pytree under
    `state["bstate"]`, so stateful backends (generational aging,
    promotion hysteresis) run inside the single-dispatch serving window.
  * `stats` is the closing window's superblock summary
    (`pool.superblock_stats`, pre-clear referenced bits).
  * `signals` are frontend→backend scalars: `proactive_ok` (the MIAD
    calm gate) and `epoch`.
  * `telemetry` is the FIXED pytree `zero_telemetry()` — same keys for
    every backend, so reports keep one structure across `lax.cond`
    branches and backend swaps.

Backends are frozen dataclasses (hashable, closed over by jitted window
programs); their fields are static hyperparameters, never arrays.

Registered backends, mirroring Figure 7's lines plus the multi-backend
scaling direction (MGLRU / TPP, cf. Jenga and HybridTier in PAPERS.md):

  reactive   — kswapd analog: demotes only under memory pressure,
               preferring MADV_COLD candidates, then unreferenced
               superblocks; referenced ones only if pressure persists
               (`evict_referenced=False` = strict kswapd, never).
  proactive  — MADV_PAGEOUT analog: immediately demotes superblocks the
               frontend marked as candidates, gated by MIAD.
  cap        — cgroup-limit analog: hard cap on resident bytes; evicts
               in address order, hot or not — the "memory-saving-first"
               baseline that tanks performance on a fragmented space.
  null       — performance-first baseline: never reclaims.
  mglru      — multi-generational LRU (stateful): per-superblock
               generation counters aged each window; under pressure,
               demote from the oldest generation first.
  promote    — watermark promotion (stateful, TPP/AutoNUMA-like):
               HOST superblocks referenced for `promote_after`
               consecutive windows re-tier to HBM under high/low
               watermark hysteresis; above the high watermark it
               demotes kswapd-style back down to it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import pool as pl

# ---------------------------------------------------------------------------
# protocol plumbing: geometry, telemetry, registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """The only configuration a backend may read: how many pages exist
    and how big they are. `pool.PoolConfig` satisfies this shape; the
    SimHeap adapter passes one of these with a 4 KiB "superblock"."""
    n_sbs: int
    sb_bytes: int


TELEMETRY_KEYS = ("be_demoted", "be_promoted")


def zero_telemetry() -> Dict[str, jax.Array]:
    """The fixed per-step backend telemetry pytree (int32 scalars) —
    identical structure for every backend so window reports keep one
    shape across `lax.cond` branches."""
    return {k: jnp.zeros((), jnp.int32) for k in TELEMETRY_KEYS}


_REGISTRY: Dict[str, type] = {}


def register(name: str):
    """Class decorator: register a Backend under `name`."""
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def names() -> Tuple[str, ...]:
    """Registered backend names (the valid `make` / `BackendConfig.kind`
    values)."""
    return tuple(sorted(_REGISTRY))


def make(name: str, **params) -> "Backend":
    """Construct a backend by registered name. Unknown names (and unknown
    params, via the dataclass constructor) are rejected HERE, at
    construction time — never inside a jitted trace."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown backend {name!r}; registered: {list(names())}")
    return _REGISTRY[name](**params)


@dataclasses.dataclass(frozen=True)
class Backend:
    """Base of the stateful backend protocol. Subclasses override `step`
    (and `init` when they carry state). See the module docstring for the
    contract; `docs/backends.md` for the long form."""

    def init(self, geom) -> Dict[str, jax.Array]:
        """Fresh backend state for `geom.n_sbs` superblocks. Stateless
        backends carry the empty pytree."""
        return {}

    def step(self, geom, bstate: Dict, stats: Dict[str, jax.Array],
             tier: jax.Array, evict: jax.Array, signals: Dict
             ) -> Tuple[Dict, jax.Array, jax.Array, Dict[str, jax.Array]]:
        raise NotImplementedError

    def _resident(self, stats, tier) -> jax.Array:
        return (stats["occupancy"] > 0) & (tier == pl.HBM)

    def _target_sbs(self, geom, target_bytes: int) -> int:
        return max(target_bytes, 0) // geom.sb_bytes  # static


# ---------------------------------------------------------------------------
# shared victim/candidate selection
# ---------------------------------------------------------------------------
def _take_k(victim_priority: jax.Array, k: jax.Array,
            min_prio: int = 0) -> jax.Array:
    """Boolean mask of the `k` highest-priority entries with priority >
    `min_prio`. Fixed-shape: a full (stable) sort, ties broken by index
    order — identical selection to the pre-registry `_demote_k`."""
    n = victim_priority.shape[0]
    order = jnp.argsort(-victim_priority)
    ranked_prio = victim_priority[order]
    take = (jnp.arange(n) < k) & (ranked_prio > min_prio)
    return jnp.zeros((n,), jnp.bool_).at[order].set(take)


def _demote(tier: jax.Array, evict: jax.Array, chosen: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    tier = jnp.where(chosen, pl.HOST, tier).astype(jnp.int8)
    evict = jnp.where(chosen, pl.PAGED_OUT, evict).astype(jnp.int8)
    return tier, evict


def _promote(tier: jax.Array, evict: jax.Array, chosen: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    tier = jnp.where(chosen, pl.HBM, tier).astype(jnp.int8)
    evict = jnp.where(chosen, pl.NORMAL, evict).astype(jnp.int8)
    return tier, evict


def _telemetry(demoted=None, promoted=None) -> Dict[str, jax.Array]:
    t = zero_telemetry()
    if demoted is not None:
        t["be_demoted"] = jnp.sum(demoted).astype(jnp.int32)
    if promoted is not None:
        t["be_promoted"] = jnp.sum(promoted).astype(jnp.int32)
    return t


# ---------------------------------------------------------------------------
# the four ported backends (bit-identical to the pre-registry `step`)
# ---------------------------------------------------------------------------
@register("null")
@dataclasses.dataclass(frozen=True)
class NullBackend(Backend):
    """Performance-first baseline: never reclaims."""

    def step(self, geom, bstate, stats, tier, evict, signals):
        return bstate, tier, evict, zero_telemetry()


@register("proactive")
@dataclasses.dataclass(frozen=True)
class ProactiveBackend(Backend):
    """MADV_PAGEOUT analog: demote every MADV_COLD candidate once MIAD
    says it's safe (`signals["proactive_ok"]`)."""

    def step(self, geom, bstate, stats, tier, evict, signals):
        do = self._resident(stats, tier) & (evict == pl.CANDIDATE) \
            & signals["proactive_ok"]
        tier, evict = _demote(tier, evict, do)
        return bstate, tier, evict, _telemetry(demoted=do)


@register("reactive")
@dataclasses.dataclass(frozen=True)
class ReactiveBackend(Backend):
    """kswapd analog. Victim priority under pressure: MADV_COLD
    candidates (3) > unreferenced (2) > referenced (1); empty or
    host-resident excluded. `evict_referenced=False` is the strict
    kswapd reading (the referenced working set is a hard memory ceiling
    — the simulator's historical behavior); True lets pressure persist
    into the active list (the framework default)."""
    hbm_target_bytes: int = 0       # pressure target
    evict_referenced: bool = True

    def step(self, geom, bstate, stats, tier, evict, signals):
        resident = self._resident(stats, tier)
        k = jnp.maximum(
            jnp.sum(resident).astype(jnp.int32)
            - self._target_sbs(geom, self.hbm_target_bytes), 0)
        prio = jnp.where(resident,
                         jnp.where(evict == pl.CANDIDATE, 3,
                                   jnp.where(~stats["referenced"], 2, 1)),
                         0)
        chosen = _take_k(prio, k,
                         min_prio=0 if self.evict_referenced else 1)
        tier, evict = _demote(tier, evict, chosen)
        return bstate, tier, evict, _telemetry(demoted=chosen)


@register("cap")
@dataclasses.dataclass(frozen=True)
class CapBackend(Backend):
    """cgroup cap: page-granular and hotness-blind — evicts resident
    superblocks in (reverse-priority = forward address) order regardless
    of referenced bits. On a fragmented address space this hits hot
    objects."""
    hbm_target_bytes: int = 0

    def step(self, geom, bstate, stats, tier, evict, signals):
        resident = self._resident(stats, tier)
        k = jnp.maximum(
            jnp.sum(resident).astype(jnp.int32)
            - self._target_sbs(geom, self.hbm_target_bytes), 0)
        n = tier.shape[0]
        prio = jnp.where(resident, n - jnp.arange(n), 0)
        chosen = _take_k(prio, k)
        tier, evict = _demote(tier, evict, chosen)
        return bstate, tier, evict, _telemetry(demoted=chosen)


# ---------------------------------------------------------------------------
# the stateful backends
# ---------------------------------------------------------------------------
@register("mglru")
@dataclasses.dataclass(frozen=True)
class MglruBackend(Backend):
    """Multi-generational LRU (MGLRU-style). Carried state: one
    generation counter per superblock. Each window, referenced resident
    superblocks join the youngest generation (0); idle resident ones age
    by one (saturating at `max_gen`); non-resident ones keep their
    generation (a fault-in is followed by a reference, which rejuvenates
    them next window). Under pressure, victims come from the OLDEST
    generation first; generations below `min_evict_gen` are protected
    (the just-referenced working set is never demoted)."""
    hbm_target_bytes: int = 0
    max_gen: int = 3
    min_evict_gen: int = 1

    def init(self, geom):
        return {"gen": jnp.zeros((geom.n_sbs,), jnp.int32)}

    def step(self, geom, bstate, stats, tier, evict, signals):
        resident = self._resident(stats, tier)
        gen = jnp.where(
            resident & stats["referenced"], 0,
            jnp.where(resident, jnp.minimum(bstate["gen"] + 1,
                                            self.max_gen),
                      bstate["gen"]))
        k = jnp.maximum(
            jnp.sum(resident).astype(jnp.int32)
            - self._target_sbs(geom, self.hbm_target_bytes), 0)
        # oldest generation first; gens < min_evict_gen excluded. The +1
        # keeps gen 0 selectable when min_evict_gen=0 (priority 0 means
        # "excluded" in _take_k) without changing the eviction order.
        prio = jnp.where(resident & (gen >= self.min_evict_gen),
                         gen + 1, 0)
        chosen = _take_k(prio, k)
        tier, evict = _demote(tier, evict, chosen)
        return ({"gen": gen}, tier, evict, _telemetry(demoted=chosen))


@register("promote")
@dataclasses.dataclass(frozen=True)
class PromoteBackend(Backend):
    """Watermark promotion (TPP/AutoNUMA-like). Carried state: a
    per-superblock count of consecutive referenced-while-on-HOST windows
    (references to HOST superblocks come from stores — loads fault the
    superblock back immediately) and the promotion hysteresis flag.

    Promotion: HOST superblocks referenced for >= `promote_after`
    consecutive windows re-tier to HBM, hottest (longest streak) first,
    never past the high watermark. Hysteresis: promotion latches off
    once the residency a step leaves behind touches the high watermark,
    and re-arms only when residency dips to the low watermark — the
    anti-ping-pong rule. Demotion:
    when residency exceeds the high watermark, superblocks are reclaimed
    kswapd-style (candidates > unreferenced > referenced) down to the
    LOW watermark — like kswapd, which reclaims past its wake-up point,
    leaving the [low, high] band as promotion headroom so the hottest
    demoted data re-tiers instead of the whole burst bouncing back.

    `hbm_high_bytes=0` means "no cap" (the whole pool); `hbm_low_bytes=0`
    collapses the hysteresis band (low = high)."""
    hbm_high_bytes: int = 0
    hbm_low_bytes: int = 0
    promote_after: int = 2

    def _watermarks(self, geom) -> Tuple[int, int]:
        high = self._target_sbs(geom, self.hbm_high_bytes) \
            if self.hbm_high_bytes > 0 else geom.n_sbs
        low = self._target_sbs(geom, self.hbm_low_bytes) \
            if self.hbm_low_bytes > 0 else high
        return high, min(low, high)

    def init(self, geom):
        return {"host_refs": jnp.zeros((geom.n_sbs,), jnp.int32),
                "active": jnp.ones((), jnp.bool_)}

    def step(self, geom, bstate, stats, tier, evict, signals):
        high, low = self._watermarks(geom)
        occupied = stats["occupancy"] > 0
        ref = stats["referenced"]
        host_res = occupied & (tier == pl.HOST)
        n_res = jnp.sum(occupied & (tier == pl.HBM)).astype(jnp.int32)

        # referenced-on-HOST streaks (reset on idle / fault-in / promote)
        refs = jnp.where(host_res & ref, bstate["host_refs"] + 1, 0)

        # hysteresis arm: held from the previous window, or re-armed the
        # moment residency dips to the low watermark
        armed = bstate["active"] | (n_res <= low)

        # promote hottest qualifying HOST sbs, never past high
        k_up = jnp.where(armed, jnp.maximum(high - n_res, 0), 0)
        up = _take_k(jnp.where(host_res & (refs >= self.promote_after),
                               refs, 0), k_up)
        tier, evict = _promote(tier, evict, up)
        refs = jnp.where(up, 0, refs)

        # above high: reclaim down to LOW (kswapd priorities; past the
        # trigger point, so the band stays open for promotion)
        resident = occupied & (tier == pl.HBM)
        n_res2 = jnp.sum(resident).astype(jnp.int32)
        k_down = jnp.where(n_res2 > high, n_res2 - low, 0)
        prio = jnp.where(resident,
                         jnp.where(evict == pl.CANDIDATE, 3,
                                   jnp.where(~ref, 2, 1)), 0)
        down = _take_k(prio, k_down)
        tier, evict = _demote(tier, evict, down)

        # latch off once the residency we LEAVE behind touches high —
        # promotion stays off until the next low-watermark dip
        r_final = jnp.sum(occupied & (tier == pl.HBM)).astype(jnp.int32)
        active = armed & (r_final < high)

        bstate = {"host_refs": refs, "active": active}
        return bstate, tier, evict, _telemetry(demoted=down, promoted=up)


# ---------------------------------------------------------------------------
# deprecated shims (pre-registry API)
# ---------------------------------------------------------------------------
def pressure_params(name: str, target_bytes: int) -> Dict[str, int]:
    """Map a generic pressure target onto whichever pressure field the
    registered backend declares (reactive/cap/mglru: hbm_target_bytes;
    promote: hbm_high_bytes; none for null/proactive). The ONE place
    that knows this mapping — launchers, the BackendConfig shim and the
    SimHeap adapter all route through it, so a new backend only has to
    name its field to pick the target up everywhere."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown backend {name!r}; registered: {list(names())}")
    if not target_bytes:
        return {}
    fields = {f.name for f in dataclasses.fields(_REGISTRY[name])}
    for field in ("hbm_target_bytes", "hbm_high_bytes"):
        if field in fields:
            return {field: target_bytes}
    return {}


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """DEPRECATED shim for the pre-registry string-keyed config. Use
    `backend.make(name, **params)` instead. Kept so existing configs and
    checkpointer metadata keep loading; `kind` is validated against the
    registry at construction time (a typo like "reactve" fails here, not
    deep inside a jitted trace)."""
    kind: str = "reactive"          # any name in backend.names()
    hbm_target_bytes: int = 0       # pressure target (reactive/cap/mglru)

    def __post_init__(self):
        if self.kind not in _REGISTRY:
            raise ValueError(
                f"unknown backend kind {self.kind!r}; "
                f"registered: {list(names())}")

    def build(self) -> Backend:
        """The equivalent registry backend, pressure target mapped via
        `pressure_params`."""
        return make(self.kind,
                    **pressure_params(self.kind, self.hbm_target_bytes))


def as_backend(obj) -> Backend:
    """Normalize a Backend | BackendConfig | name string to a Backend."""
    if isinstance(obj, Backend):
        return obj
    if isinstance(obj, BackendConfig):
        return obj.build()
    if isinstance(obj, str):
        return make(obj)
    raise TypeError(f"not a backend: {obj!r}")


def step(cfg, pool_cfg: pl.PoolConfig, stats: Dict[str, jax.Array],
         tier: jax.Array, evict: jax.Array, proactive_ok: jax.Array
         ) -> Tuple[jax.Array, jax.Array]:
    """DEPRECATED shim for the pre-registry stateless entry point.
    Runs one protocol step with fresh state and drops the carried state
    and telemetry — stateless backends are unaffected; stateful ones
    need the real protocol (`Engine` threads bstate automatically)."""
    b = as_backend(cfg)
    _, tier, evict, _ = b.step(pool_cfg, b.init(pool_cfg), stats, tier,
                               evict, {"proactive_ok": proactive_ok,
                                       "epoch": jnp.zeros((), jnp.int32)})
    return tier, evict
