"""Page-level reclamation backends (paper §3.3 / §5.2).

Backends are *object-oblivious by construction*: their only input is the
per-superblock summary from `pool.superblock_stats` (occupancy, referenced
bit, region id, tier, evict state) — the same information the kernel's page
reclaim has (PTE accessed bits + LRU lists). They never see the object
table. This enforces the paper's decoupling: the frontend engineers the
address space; an unmodified backend acts on pages.

Four backends, mirroring Figure 7's lines:

  ReactiveBackend   — kswapd analog: demotes only under memory pressure,
                      preferring unreferenced superblocks (inactive list),
                      then MADV_COLD candidates, never referenced ones
                      unless pressure persists.
  ProactiveBackend  — MADV_PAGEOUT analog: immediately demotes superblocks
                      the frontend marked as candidates, gated by MIAD
                      (`proactive_ok`).
  CapBackend        — cgroup-limit analog: hard cap on resident bytes;
                      evicts in address order, hot or not — the
                      "memory-saving-first" baseline that tanks performance
                      on a fragmented address space.
  NullBackend       — performance-first baseline: never reclaims.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import pool as pl


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    kind: str = "reactive"          # reactive | proactive | cap | null
    hbm_target_bytes: int = 0       # pressure target (0 = no pressure)


def _demote_k(tier: jax.Array, evict: jax.Array, victim_priority: jax.Array,
              k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Demote the `k` highest-priority victims (priority > 0) to HOST.
    Returns (tier, evict). Fixed-shape: uses a full sort over superblocks."""
    n = tier.shape[0]
    # sort descending by priority; take first k with priority > 0
    order = jnp.argsort(-victim_priority)
    ranked_prio = victim_priority[order]
    take = (jnp.arange(n) < k) & (ranked_prio > 0)
    chosen = jnp.zeros((n,), jnp.bool_).at[order].set(take)
    tier = jnp.where(chosen, pl.HOST, tier).astype(jnp.int8)
    evict = jnp.where(chosen, pl.PAGED_OUT, evict).astype(jnp.int8)
    return tier, evict


def step(cfg: BackendConfig, pool_cfg: pl.PoolConfig,
         stats: Dict[str, jax.Array], tier: jax.Array, evict: jax.Array,
         proactive_ok: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One backend pass over superblock summaries -> new (tier, evict).

    `stats` comes from pool.superblock_stats — page-level info only.
    """
    occ = stats["occupancy"]
    ref = stats["referenced"]
    resident = (occ > 0) & (tier == pl.HBM)

    if cfg.kind == "null":
        return tier, evict

    if cfg.kind == "proactive":
        # Demote every MADV_COLD candidate once MIAD says it's safe.
        do = resident & (evict == pl.CANDIDATE) & proactive_ok
        tier = jnp.where(do, pl.HOST, tier).astype(jnp.int8)
        evict = jnp.where(do, pl.PAGED_OUT, evict).astype(jnp.int8)
        return tier, evict

    # pressure-driven backends: how many superblocks over target?
    target_sbs = max(cfg.hbm_target_bytes, 0) // pool_cfg.sb_bytes  # static
    k = jnp.maximum(jnp.sum(resident).astype(jnp.int32) - target_sbs, 0)

    if cfg.kind == "reactive":
        # kswapd-like victim priority: candidates (3) > unreferenced (2)
        # > referenced (1); empty/host-resident excluded (0).
        prio = jnp.where(resident,
                         jnp.where(evict == pl.CANDIDATE, 3,
                                   jnp.where(~ref, 2, 1)), 0)
        return _demote_k(tier, evict, prio, k)

    if cfg.kind == "cap":
        # cgroup cap: page-granular and hotness-blind — evicts resident
        # superblocks in (reverse) address order regardless of referenced
        # bits. On a fragmented address space this hits hot objects.
        n = tier.shape[0]
        prio = jnp.where(resident, n - jnp.arange(n), 0)
        return _demote_k(tier, evict, prio, k)

    raise ValueError(cfg.kind)
