"""SimHeap — byte-granular virtual-address-space simulator (numpy).

The jit pool (`core/pool.py`) manages fixed-size framework objects. The
paper's *evaluation*, though, is about C++ heaps: variable-size objects
(30 B keys, 1024 B values, index nodes), 4 KiB pages, 2 MiB huge pages,
kswapd/madvise backends. SimHeap reproduces that environment faithfully —
it tracks *placement* (addresses), not payloads, so 10M-key YCSB runs fit
in metadata memory.

Semantics mirrored from HADES:
  * three heaps as contiguous address ranges (NEW / HOT / COLD);
  * bump allocation + collector-time compaction (pointers are updatable
    through the object table — that is the paper's enabling insight);
  * per-object access bit / CIW / ATC words, identical state machine;
  * MIAD feedback on the COLD-heap promotion rate;
  * page-level backends that see only page metadata: resident,
    referenced, evict-candidate — the SAME `core.backend` registry
    implementations the jit Engine runs (one oracle), adapted to 4 KiB
    pages via `PageGeometry` (`backend_step` below); stateful backends
    (mglru generations, promote watermarks) carry their state on the
    heap across windows;
  * page faults promote pages back and cost `fault_ns` (P4800x-class);
  * huge-page promotion of dense 2 MiB runs in the HOT heap; THP-style
    bloat is visible if promotion is applied to sparse runs.

Cost model (fig 6c): every tracked access pays `track_ns` (the 4–5 ns
access-bit op); the first observation of an object in a window pays the
scope-guard O(log N) term; faults pay `fault_ns`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import backend as be
from repro.core import pool as pl

NEW, HOT, COLD = 0, 1, 2
PAGE = 4096
HUGE = 2 * 1024 * 1024
ALIGN = 16


@dataclasses.dataclass
class SimConfig:
    max_objects: int
    heap_bytes: int                 # per-heap address range
    backend: str = "reactive"       # any registered backend.names() entry
    hbm_target_bytes: int = 0       # pressure target / promote watermark
    ciw_threshold: float = 3.0
    ciw_min: float = 1.0
    ciw_max: float = 16.0
    promotion_target: float = 0.01
    miad_mult: float = 2.0
    miad_add: float = 1.0
    calm_required: int = 2
    enabled: bool = True            # False = no tidying (baseline layout)
    track_ns: float = 4.5           # access-bit SET (paper: 4-5 ns, L1-ish)
    check_ns: float = 0.5           # already-set fast path ("skip if set")
    guard_ns: float = 1.0           # scope-guard cost per log2(N) level
    fault_ns: float = 15_000.0      # SSD swap fault (P4800x-class)
    base_op_ns: float = 1_500.0     # baseline cost of one KV op (CrestDB)
    huge_occupancy: float = 0.90    # hugepage promotion threshold


class SimHeap:
    """Trace-driven address-space engine. All ops are vectorized."""

    def __init__(self, cfg: SimConfig, seed: int = 0):
        self.cfg = cfg
        n = cfg.max_objects
        self.addr = np.full(n, -1, np.int64)       # byte address
        self.size = np.zeros(n, np.int64)
        self.heap = np.full(n, -1, np.int8)        # -1 = free
        self.access = np.zeros(n, bool)
        self.ciw = np.zeros(n, np.int16)
        self.atc = np.zeros(n, np.int16)
        self.armed = False
        # bump cursors per heap (addresses are heap-relative + heap base)
        self.base = {NEW: 0, HOT: cfg.heap_bytes, COLD: 2 * cfg.heap_bytes}
        self.cursor = {NEW: 0, HOT: 0, COLD: 0}
        self.live_bytes = {NEW: 0, HOT: 0, COLD: 0}
        # page metadata over the whole 3-heap address space
        self.n_pages = (3 * cfg.heap_bytes) // PAGE
        self.resident = np.zeros(self.n_pages, bool)
        self.referenced = np.zeros(self.n_pages, bool)
        self.evict = np.zeros(self.n_pages, np.int8)  # 0/1 cand/2 out
        # shared tiering backend (core.backend registry): a 4 KiB page
        # plays the superblock role; unknown names fail HERE, at
        # construction. `reactive` runs in strict-kswapd mode (never
        # evicts referenced pages — the simulator's historical ceiling).
        self._geom = be.PageGeometry(n_sbs=self.n_pages, sb_bytes=PAGE)
        self.backend = self._make_backend(cfg)
        self._bstate = self.backend.init(self._geom)
        # MIAD state
        self.ciw_threshold = cfg.ciw_threshold
        self.calm_windows = 0
        self.proactive_ok = False
        # window + lifetime counters
        self.win_accesses = 0
        self.win_promos = 0
        self.win_first_obs = 0
        self.win_faults = 0
        self.win_track_ops = 0
        self.epoch = 0
        self.total_faults = 0
        self.total_moves = 0
        self.total_ns = 0.0
        self.window_log: list = []

    # -- allocation ---------------------------------------------------------
    def alloc(self, ids: np.ndarray, sizes: np.ndarray,
              heap: int = NEW) -> None:
        """Bump-allocate objects into `heap` (NEW unless placing an
        un-tidied baseline, which scatters everything into one heap)."""
        ids = np.asarray(ids, np.int64)
        sizes = np.asarray(sizes, np.int64)
        aligned = (sizes + ALIGN - 1) // ALIGN * ALIGN
        offs = np.cumsum(aligned) - aligned
        start = self.cursor[heap]
        need = int(offs[-1] + aligned[-1]) if len(ids) else 0
        if start + need > self.cfg.heap_bytes:
            self._compact(heap)
            start = self.cursor[heap]
            if start + need > self.cfg.heap_bytes:
                raise MemoryError(f"heap {heap} exhausted")
        addrs = self.base[heap] + start + offs
        self.addr[ids] = addrs
        self.size[ids] = sizes
        self.heap[ids] = heap
        self.access[ids] = True
        self.ciw[ids] = 0
        self.cursor[heap] = start + need
        self.live_bytes[heap] += int(aligned.sum())
        self._touch_pages(addrs, sizes, fault=True)
        self.win_accesses += len(ids)

    def free(self, ids: np.ndarray) -> None:
        ids = np.asarray(ids, np.int64)
        ids = ids[self.heap[ids] >= 0]
        aligned = (self.size[ids] + ALIGN - 1) // ALIGN * ALIGN
        for h in (NEW, HOT, COLD):
            self.live_bytes[h] -= int(aligned[self.heap[ids] == h].sum())
        self.heap[ids] = -1
        self.addr[ids] = -1

    # -- access (the dereference) --------------------------------------------
    def access_objects(self, ids: np.ndarray) -> None:
        """Record accesses (duplicates allowed — dedup is the 'skip if
        already set' fast path)."""
        ids = np.asarray(ids, np.int64)
        ids = ids[self.heap[ids] >= 0]
        if len(ids) == 0:
            return
        uniq = np.unique(ids)
        newly = ~self.access[uniq]
        self.win_first_obs += int(newly.sum())
        self.access[uniq] = True
        if self.armed:
            np.add.at(self.atc, ids, 1)
        self.win_promos += int((self.heap[uniq] == COLD).sum())
        self.win_accesses += len(ids)
        self.win_track_ops += len(ids)
        self._touch_pages(self.addr[uniq], self.size[uniq], fault=True)

    @staticmethod
    def _page_ranges(addrs: np.ndarray, sizes: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Expand per-object [first, last] page spans into one flat page
        array + the object index each entry came from. Ragged-range via
        repeat/cumsum: O(total touched pages), independent of the max
        object span (the old per-span Python loop was O(max span) full
        passes over the batch)."""
        first = addrs // PAGE
        last = (addrs + np.maximum(sizes, 1) - 1) // PAGE
        counts = (last - first + 1).astype(np.int64)
        owner = np.repeat(np.arange(len(addrs)), counts)
        # offset within each object's span: global arange minus each
        # span's starting position, broadcast by repeat
        starts = np.cumsum(counts) - counts
        offs = np.arange(counts.sum(), dtype=np.int64) - np.repeat(starts,
                                                                   counts)
        return np.repeat(first, counts) + offs, owner

    def _touch_pages(self, addrs: np.ndarray, sizes: np.ndarray,
                     fault: bool) -> None:
        if len(addrs) == 0:
            return
        pages, _ = self._page_ranges(addrs, sizes)
        pages = np.unique(pages)
        out = pages[self.evict[pages] == 2]
        self.win_faults += len(out)
        self.total_faults += len(out)
        self.evict[pages] = 0
        self.resident[pages] = True
        self.referenced[pages] = True

    # -- collector ------------------------------------------------------------
    def arm(self) -> None:
        self.armed = True

    def collect(self) -> Dict[str, float]:
        """Object Collector pass: CIW update, classification, migration,
        compaction, MIAD, backend handoff signals."""
        cfg = self.cfg
        live = self.heap >= 0
        acc = self.access & live
        self.ciw[acc] = 0
        idle = live & ~self.access
        self.ciw[idle] = np.minimum(self.ciw[idle] + 1, 31)

        report = {"promotion_rate": self.promotion_rate(),
                  "epoch": self.epoch}
        if cfg.enabled:
            ct = math.floor(self.ciw_threshold)
            movable = self.atc == 0
            to_hot = acc & np.isin(self.heap, (NEW, COLD)) & movable
            to_cold = idle & (self.ciw > ct) & \
                np.isin(self.heap, (NEW, HOT)) & movable
            self._migrate(np.nonzero(to_hot)[0], HOT)
            self._migrate(np.nonzero(to_cold)[0], COLD)
            report["moved_to_hot"] = int(to_hot.sum())
            report["moved_to_cold"] = int(to_cold.sum())
            # Compact NEW/HOT when >30% holes. The COLD heap is NEVER
            # compacted in normal operation: its pages may be paged out,
            # and touching them would fault the whole point away. It is
            # compacted only on emergency (migration target full), with
            # the fault cost charged honestly (_compact counts them).
            for h in (NEW, HOT):
                if self.cursor[h] > 1.3 * max(self.live_bytes[h], 1):
                    self._compact(h)

        # MIAD
        rate = self.promotion_rate()
        if rate > cfg.promotion_target:
            self.ciw_threshold = min(self.ciw_threshold * cfg.miad_mult,
                                     cfg.ciw_max)
            self.calm_windows = 0
        else:
            self.ciw_threshold = max(self.ciw_threshold - cfg.miad_add,
                                     cfg.ciw_min)
            self.calm_windows += 1
        self.proactive_ok = self.calm_windows >= cfg.calm_required

        # frontend -> backend signal: fully-cold COLD-heap pages -> MADV_COLD
        if cfg.enabled:
            lo = self.base[COLD] // PAGE
            hi = (self.base[COLD] + self.cursor[COLD]) // PAGE + 1
            cand = self.resident[lo:hi] & ~self.referenced[lo:hi] & \
                (self.evict[lo:hi] == 0)
            self.evict[lo:hi][cand] = 1

        # window accounting -> overhead model. Instrumentation costs apply
        # only when HADES is enabled (no tracking in the baseline); fault
        # penalties always apply (they are the backend's, not HADES').
        ns = self.win_faults * cfg.fault_ns
        if cfg.enabled:
            log_n = max(math.log2(max(int(live.sum()), 2)), 1.0)
            ns += (self.win_first_obs * (cfg.track_ns + cfg.guard_ns * log_n)
                   + (self.win_track_ops - self.win_first_obs) * cfg.check_ns)
        self.total_ns += ns
        report.update(window_overhead_ns=ns, faults=self.win_faults,
                      accesses=self.win_accesses,
                      page_utilization=self.page_utilization(),
                      rss_bytes=self.rss_bytes(),
                      ciw_threshold=self.ciw_threshold)
        self.window_log.append(report)

        # reset window state (backends act on the CLOSING window's
        # referenced bits — snapshot before clearing)
        self.last_referenced = self.referenced.copy()
        self.access[:] = False
        self.atc[:] = 0
        self.armed = False
        self.referenced[:] = False
        self.win_accesses = self.win_promos = 0
        self.win_first_obs = self.win_faults = self.win_track_ops = 0
        self.epoch += 1
        return report

    def _migrate(self, ids: np.ndarray, dest: int) -> None:
        if len(ids) == 0:
            return
        sizes = self.size[ids]
        aligned = (sizes + ALIGN - 1) // ALIGN * ALIGN
        offs = np.cumsum(aligned) - aligned
        need = int(offs[-1] + aligned[-1])
        if self.cursor[dest] + need > self.cfg.heap_bytes:
            self._compact(dest)
            if self.cursor[dest] + need > self.cfg.heap_bytes:
                return  # dest full: skip this window (forward progress)
        for h in (NEW, HOT, COLD):
            sel = self.heap[ids] == h
            self.live_bytes[h] -= int(aligned[sel].sum())
        self.addr[ids] = self.base[dest] + self.cursor[dest] + offs
        self.heap[ids] = dest
        self.cursor[dest] += need
        self.live_bytes[dest] += need
        self.total_moves += len(ids)
        self._touch_pages(self.addr[ids], sizes, fault=False)

    def _compact(self, heap: int) -> None:
        """Slide live objects to the heap base (table-mediated pointer
        rewrite — no application involvement). Compacting a region with
        paged-out pages faults them in first — charged to the window."""
        lo_pg = self.base[heap] // PAGE
        hi_pg = (self.base[heap] + self.cursor[heap]) // PAGE + 1
        paged_out = int((self.evict[lo_pg:hi_pg] == 2).sum())
        self.win_faults += paged_out
        self.total_faults += paged_out
        ids = np.nonzero(self.heap == heap)[0]
        if len(ids):
            order = np.argsort(self.addr[ids], kind="stable")
            ids = ids[order]
            aligned = (self.size[ids] + ALIGN - 1) // ALIGN * ALIGN
            offs = np.cumsum(aligned) - aligned
            self.addr[ids] = self.base[heap] + offs
            end = int(offs[-1] + aligned[-1])
        else:
            end = 0
        # the compacted prefix was written to (resident); pages beyond the
        # new cursor are free
        plo = self.base[heap] // PAGE
        pmid = (self.base[heap] + end + PAGE - 1) // PAGE
        phi = (self.base[heap] + self.cfg.heap_bytes) // PAGE
        self.resident[plo:pmid] = True
        self.evict[plo:pmid] = 0
        self.resident[pmid:phi] = False
        self.evict[pmid:phi] = 0
        self.cursor[heap] = end
        self.live_bytes[heap] = end

    # -- backend (page-level, object-oblivious) --------------------------------
    # The pure-python adapter onto the shared `core.backend` protocol:
    # page metadata in, protocol stats out, backend deltas applied back.
    # The numpy duplicate of the backend logic is GONE — simulation and
    # production run one implementation (the repo's single oracle).
    @staticmethod
    def _make_backend(cfg: SimConfig) -> be.Backend:
        params = be.pressure_params(cfg.backend, cfg.hbm_target_bytes)
        if cfg.backend == "reactive":
            # strict kswapd: the referenced set is a hard memory ceiling
            # (bit-identical to the pre-protocol numpy backend)
            params["evict_referenced"] = False
        return be.make(cfg.backend, **params)

    def page_stats(self) -> Tuple[Dict[str, np.ndarray], np.ndarray,
                                  np.ndarray]:
        """The backend protocol's (stats, tier, evict) view of the page
        metadata: occupied = resident or paged out; tier HOST iff paged
        out; referenced = the CLOSING window's bits (post-collect
        snapshot). The parity suite replays these through the jit path."""
        out = self.evict == 2
        occ = (self.resident | out).astype(np.int32)
        ref = getattr(self, "last_referenced", self.referenced)
        region = np.full(self.n_pages, COLD, np.int8)
        for h in (NEW, HOT):
            lo = self.base[h] // PAGE
            region[lo:lo + self.cfg.heap_bytes // PAGE] = h
        tier = np.where(out, pl.HOST, pl.HBM).astype(np.int8)
        stats = {"occupancy": occ, "referenced": ref.copy(),
                 "region": region, "tier": tier, "evict": self.evict.copy()}
        return stats, tier, self.evict.astype(np.int8)

    def backend_step(self) -> None:
        stats, tier, evict = self.page_stats()
        signals = {"proactive_ok": np.bool_(self.proactive_ok),
                   "epoch": np.int32(self.epoch)}
        self._bstate, tier2, evict2, _ = self.backend.step(
            self._geom, self._bstate, stats, tier, evict, signals)
        tier2 = np.asarray(tier2)
        # apply the backend's outputs verbatim: the full evict column
        # (so a backend that marks/clears evict state without re-tiering
        # still round-trips through the adapter) + residency from the
        # tier deltas
        self.evict = np.asarray(evict2).astype(np.int8).copy()
        demoted = (tier == pl.HBM) & (tier2 == pl.HOST)   # paged out
        promoted = (tier == pl.HOST) & (tier2 == pl.HBM)  # re-tiered in
        self.resident[demoted] = False
        self.resident[promoted] = True

    # -- metrics ----------------------------------------------------------------
    def promotion_rate(self) -> float:
        return self.win_promos / max(self.win_accesses, 1)

    def page_utilization(self) -> float:
        """Unique accessed bytes / (touched pages x 4 KiB), this window."""
        live = (self.heap >= 0) & self.access
        if not live.any():
            return 1.0
        ids = np.nonzero(live)[0]
        ubytes = int(self.size[ids].sum())
        pages, _ = self._page_ranges(self.addr[ids], self.size[ids])
        return ubytes / (len(np.unique(pages)) * PAGE)

    def per_page_utilization(self) -> np.ndarray:
        """Utilized fraction of every page touched this window (fig 2's
        CDF): accessed bytes landing on each page / 4096."""
        live = (self.heap >= 0) & self.access
        if not live.any():
            return np.ones(1)
        ids = np.nonzero(live)[0]
        addr, size = self.addr[ids], self.size[ids]
        acc = np.zeros(self.n_pages, np.int64)
        pg, owner = self._page_ranges(addr, size)
        # bytes of each owning object landing on each of its pages
        start = np.maximum(addr[owner], pg * PAGE)
        end = np.minimum(addr[owner] + size[owner], (pg + 1) * PAGE)
        np.add.at(acc, pg, np.maximum(end - start, 0))
        touched = acc[acc > 0]
        return np.minimum(touched / PAGE, 1.0)

    def rss_bytes(self) -> int:
        """Resident bytes, honouring hugepage rounding in the HOT heap:
        a 2 MiB run that crossed the occupancy threshold is counted fully
        (it is mapped as one huge page)."""
        base_rss = int(self.resident.sum()) * PAGE
        lo = self.base[HOT] // PAGE
        hi = (self.base[HOT] + self.cursor[HOT]) // PAGE + 1
        hot_pages = self.resident[lo:hi]
        per_huge = HUGE // PAGE
        n_runs = len(hot_pages) // per_huge
        if n_runs:
            runs = hot_pages[:n_runs * per_huge].reshape(n_runs, per_huge)
            occ = runs.mean(axis=1)
            promoted = occ >= self.cfg.huge_occupancy
            # promoted runs are counted fully; their sparse remainder is
            # the THP-bloat term
            bloat = int(((1 - runs[promoted].mean(axis=1)) *
                         HUGE).sum()) if promoted.any() else 0
            base_rss += bloat
        return base_rss

    def touched_bytes(self) -> int:
        live = (self.heap >= 0) & self.access
        return int(self.size[live].sum())

    def overhead_ns(self) -> float:
        return self.total_ns
