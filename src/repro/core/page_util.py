"""Page Utilization — the paper's hotness-fragmentation metric (§2).

    PageUtilization(T) = TotalUniqueBytes(T) / (UniquePages(T) * PageSize)

Low values mean hot bytes are scattered thinly over many pages — the
address space is fragmented and pages are unreclaimable despite being
mostly cold. HADES drives this metric up by densifying hot objects.

Two entry points:
  * `from_access_log` — exact, trace-driven (CrestKV simulator / fig 2, 6a):
    unique bytes and unique pages from (address, size) access records.
  * `from_pool` — jit-path variant over a HadesPool window: object access
    bits + slot geometry, at the pool's page granularity.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import object_table as ot
from repro.core import pool as pl


def from_arrays(addrs: np.ndarray, sizes: np.ndarray,
                page_size: int = 4096) -> float:
    """Exact Page Utilization from raw byte accesses (numpy, trace-driven).
    addrs/sizes: int64 arrays of access records (may repeat)."""
    if len(addrs) == 0:
        return 1.0
    addrs = np.asarray(addrs, np.int64)
    sizes = np.asarray(sizes, np.int64)
    # unique bytes: merge [addr, addr+size) intervals
    order = np.argsort(addrs, kind="stable")
    a = addrs[order]
    e = a + sizes[order]
    run_end = np.maximum.accumulate(e)
    new_run = np.ones(len(a), bool)
    new_run[1:] = a[1:] > run_end[:-1]
    run_id = np.cumsum(new_run) - 1
    starts = a[new_run]
    ends = np.zeros(run_id.max() + 1, np.int64)
    np.maximum.at(ends, run_id, e)
    unique_bytes = int(np.sum(ends - starts))
    # unique pages touched by any record
    first_pg = a // page_size
    last_pg = (e - 1) // page_size
    # expand ranges (records rarely span >2 pages for small objects)
    max_span = int(np.max(last_pg - first_pg)) + 1
    pages = np.concatenate([
        np.unique(np.minimum(first_pg + i, last_pg))
        for i in range(max_span)])
    unique_pages = len(np.unique(pages))
    return unique_bytes / float(unique_pages * page_size)


def from_pool(cfg: pl.PoolConfig, state: Dict) -> jax.Array:
    """Window Page Utilization over a HadesPool: objects whose access bit is
    set, at `cfg.page_slots` page granularity. Jit-safe."""
    tbl = state["table"]
    acc = (ot.access_of(tbl) == 1) & ot.is_live(tbl)
    slots = ot.slot_of(tbl).astype(jnp.int32)
    n_pages = cfg.n_slots // cfg.page_slots
    page = slots // cfg.page_slots
    touched = jnp.zeros((n_pages,), jnp.bool_).at[
        jnp.where(acc, page, n_pages)].set(True, mode="drop")
    unique_bytes = jnp.sum(acc).astype(jnp.float32) * cfg.slot_bytes
    page_bytes = jnp.sum(touched).astype(jnp.float32) * \
        cfg.page_slots * cfg.slot_bytes
    return unique_bytes / jnp.maximum(page_bytes, 1.0)
