"""MIAD feedback control (paper §4, "Adaptive Workload Response").

The promotion rate — the fraction of window accesses that hit the COLD
heap — is the proxy for page-fault pressure. Adapting TCP congestion
control, the demotion threshold C_t follows a *multiplicative increase /
additive decrease* (MIAD) law:

    promo_rate > target  ->  C_t <- min(C_t * mult, C_max)   (back off:
                             objects must be cold for longer to demote)
    promo_rate <= target ->  C_t <- max(C_t - add, C_min)    (lean in)

The same signal gates backend escalation: reclamation stays *reactive*
(MADV_COLD candidates only) until the promotion rate has been safely below
target for `calm_required` consecutive windows, then *proactive*
(MADV_PAGEOUT) demotion unlocks. A single hot window de-escalates.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MiadConfig:
    target: float = 0.01      # promotion-rate target (paper: ~1%)
    mult: float = 2.0         # multiplicative increase of C_t
    add: float = 1.0          # additive decrease of C_t
    c_min: float = 1.0
    c_max: float = 16.0
    calm_required: int = 2    # calm windows before PAGEOUT unlocks


def promotion_rate(win_promos: jax.Array, win_accesses: jax.Array
                   ) -> jax.Array:
    return win_promos.astype(jnp.float32) / jnp.maximum(
        win_accesses.astype(jnp.float32), 1.0)


def update(cfg: MiadConfig, ciw_threshold: jax.Array,
           calm_windows: jax.Array, win_promos: jax.Array,
           win_accesses: jax.Array
           ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One MIAD step. Returns (new_C_t, new_calm_windows, promo_rate,
    proactive_ok)."""
    rate = promotion_rate(win_promos, win_accesses)
    hot = rate > cfg.target
    new_ct = jnp.where(hot,
                       jnp.minimum(ciw_threshold * cfg.mult, cfg.c_max),
                       jnp.maximum(ciw_threshold - cfg.add, cfg.c_min))
    calm = jnp.where(hot, 0, calm_windows + 1)
    proactive_ok = calm >= cfg.calm_required
    return new_ct, calm, rate, proactive_ok
