"""Production meshes (TPU v5e pods).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
axis carries cross-pod data parallelism (gradient all-reduce over DCI);
data/model stay intra-pod on ICI.

`make_production_mesh` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — shared by roofline + kernels
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
