"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh, three terms (seconds):

    compute    = HLO_FLOPs_per_chip / 197e12          (cost_analysis)
    memory     = modeled_HBM_bytes_per_chip / 819e9   (analytic, below)
    collective = collective_bytes / (chips * 50e9)    (HLO text parse)

FLOPs come from compiled.cost_analysis() of the unrolled probes (linear
per-unit extrapolation, dryrun.run_cell). Collective bytes from summing
operand sizes of every all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute in the post-SPMD HLO.

The MEMORY term is analytic: XLA:CPU's "bytes accessed" counts every
HLO op's operands UNFUSED — on a fused TPU program it overestimates HBM
traffic ~10-30x (we report it as `xla_bytes`, an upper bound). The model
counts, per chip: weight streams (incl. FSDP regathers), optimizer
state, activation traffic (incl. remat recompute), logits, KV-cache and
MoE expert streams — formulas in `modeled_bytes`.

Roofline fraction (the §Perf score):
    t_useful = max(MODEL_FLOPS_time, minimal_bytes_time)
    frac     = t_useful / max(compute, memory, collective)
`minimal_bytes` is the mandatory traffic (each param/KV byte touched
once, no regathers, active experts only) — so frac < 1 decomposes into
remat waste, regather waste, cold-expert streaming, dispatch overhead.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

MSZ = DSZ = 16          # single-pod mesh axes
ACT_C_ATTN = 12.0       # activation r/w per layer (flash-fused + remat)
ACT_C_SSM = 24.0        # mamba: d_in = 2*d_model wide intermediates


def model_flops(rec: Dict) -> float:
    n = rec["active_params"]
    d = rec["tokens"]
    return (6.0 if rec["mode"] == "train" else 2.0) * n * d


def _arch_bytes(cfg, shape, chips: int, minimal: bool) -> float:
    """Per-chip HBM bytes of one step (modeled or minimal)."""
    spec = SHAPES[shape]
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    L = cfg.num_layers
    D = cfg.d_model
    V = cfg.vocab_size
    T = spec.global_batch * (spec.seq_len if spec.mode != "decode" else 1)
    t_local = T / DSZ                     # batch shards over data only
    n_ssm = sum(1 for b in cfg.blocks if b in ("mamba1", "mamba2"))
    n_attn = L - n_ssm
    act_c = (n_attn * ACT_C_ATTN + n_ssm * ACT_C_SSM) / max(L, 1)
    if minimal:
        act_c /= 3.0                      # no remat recompute, perfect fusion

    # --- weight streams ---
    if spec.mode == "train":
        if minimal:
            w = 2.0 * P / chips * 3       # fwd+bwd+grad, ideally sharded
            opt = 20.0 * P / chips        # m,v fp32 r/w + param update
        else:
            # FSDP regathers: each chip reads its model-axis shard of the
            # FULL weights for fwd, again for bwd (remat), grads
            # reduce-scatter r/w
            w = 2.0 * P / MSZ * 3
            opt = 20.0 * P / chips
    elif spec.mode == "prefill":
        # prefill gathers its model-shard of the weights per layer
        w = 2.0 * P / (chips if minimal else MSZ)
        opt = 0.0
    else:
        # decode: the compiled HLO shows XLA keeps the 2-D-sharded weight
        # shards LOCAL and all-reduces the tiny [B,1,*] partial sums (the
        # measured collective bytes are ~MB/step) — per-chip weight
        # traffic is the local shard, NOT a regather. (Iteration 0 of
        # §Perf: the regather hypothesis was REFUTED by the HLO.)
        dense_w = 2.0 * (Pa if minimal else P)
        if cfg.num_experts:
            # the gathered path is exact+profitable only when the step's
            # routed-slot count stays under E (models/transformer.py)
            gate = T * cfg.experts_per_token < cfg.num_experts
            use_gather = getattr(cfg.hades, "expert_gather_decode",
                                 False) and gate
            if minimal and not gate:
                dense_w = 2.0 * P         # all experts genuinely hit
            elif minimal or use_gather:
                dense_w = 2.0 * Pa        # HADES: routed experts only
            else:
                dense_w = 2.0 * P         # dropless streams ALL experts
        w = dense_w / chips
        opt = 0.0

    # --- activations ---
    act = L * t_local * D * 2.0 * act_c
    if spec.mode == "train":
        act *= 1.0                        # fwd+bwd already in act_c
    if minimal:
        act = L * t_local * D * 2.0 * 4.0

    # --- logits ---
    logits = t_local * (V / MSZ) * 4.0 * 2.0
    if minimal:
        logits = t_local * V / chips * 4.0

    # --- attention state (decode KV / prefill KV write) ---
    kv = 0.0
    hd = cfg.resolved_head_dim
    n_kv = cfg.num_kv_heads
    if spec.mode == "decode" and n_attn > 0:
        c_len = min(spec.seq_len, cfg.sliding_window) \
            if cfg.sliding_window else spec.seq_len
        total_kv = n_attn * spec.global_batch * c_len * n_kv * hd * 2 * 2
        if cfg.family == "hybrid":
            total_kv = (L // cfg.shared_attn_every) * spec.global_batch \
                * c_len * n_kv * hd * 2 * 2
        if getattr(cfg.hades, "kv_quant_bits", 16) == 8 and not minimal:
            total_kv *= 0.5625            # int8 + per-block scales
        kv = total_kv / chips             # cache is fully sharded (B, C)
    if cfg.is_encoder_decoder and spec.mode != "decode":
        kv += cfg.encoder_seq_len * spec.global_batch / DSZ * D * 2 * 4

    # --- SSM state (decode) ---
    ssm = 0.0
    if spec.mode == "decode" and n_ssm > 0:
        din = D * cfg.ssm_expand
        ssm = n_ssm * spec.global_batch * din * cfg.ssm_state_dim * 4 * 2
        ssm /= chips if spec.global_batch >= chips else 1

    return w + opt + act + logits + kv + ssm


def analyse(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec or "flops" not in rec:
        return None
    chips = rec["chips"]
    cfg = get_config(rec["arch"])
    if rec.get("expert_gather") or rec.get("kv_bits", 16) != 16:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, hades=_dc.replace(
            cfg.hades,
            expert_gather_decode=bool(rec.get("expert_gather")),
            kv_quant_bits=rec.get("kv_bits", 16)))
    flops_dev = max(rec["flops"], rec.get("flops_rolled", 0.0))
    t_compute = flops_dev / PEAK_FLOPS_BF16
    modeled = _arch_bytes(cfg, rec["shape"], chips, minimal=False)
    minimal = _arch_bytes(cfg, rec["shape"], chips, minimal=True)
    t_memory = modeled / HBM_BW
    coll = rec["collective_bytes"]
    if coll <= 0 and "probe" in rec:
        # SPMD's "involuntary full remat" at tiny probe sizes can make
        # c2 < c1; fall back to per-unit = c2/2 (the 2-unit probe split)
        coll = rec["probe"]["c2"] / 2.0 * rec.get("n_units", 1)
    t_coll = max(coll, 0.0) / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]
    mf = model_flops(rec)
    t_ideal = max(mf / (chips * PEAK_FLOPS_BF16), minimal / HBM_BW)
    return {
        "cell": rec["cell"], "arch": rec["arch"], "shape": rec["shape"],
        "chips": chips, "mode": rec["mode"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": flops_dev * chips,
        "useful_ratio": mf / (flops_dev * chips) if flops_dev else 0.0,
        "xla_bytes_dev": rec.get("bytes_accessed", 0.0),
        "modeled_bytes_dev": modeled, "minimal_bytes_dev": minimal,
        # capped at 1.0: qwen2-vl's HLO flops land ~17% under 6ND due to
        # SPMD replication noise in the probes (noted in EXPERIMENTS.md)
        "roofline_frac": min(t_ideal / t_bound, 1.0) if t_bound > 0
        else 0.0,
    }


def load_all(d: str, mesh: str = "pod256") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, f"*_{mesh}.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        a = analyse(rec)
        if a:
            out.append(a)
    return out


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} x {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def to_csv(rows: List[Dict]) -> str:
    cols = ["arch", "shape", "chips", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_frac"]
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r[c]) for c in cols))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod256")
    ap.add_argument("--fmt", default="md", choices=("md", "csv"))
    args = ap.parse_args()
    rows = load_all(args.dir, args.mesh)
    print(to_markdown(rows) if args.fmt == "md" else to_csv(rows))
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"])
        coll = max(rows, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['cell']} "
              f"({worst['roofline_frac']:.3f})")
        print(f"most collective-bound:  {coll['cell']} "
              f"({coll['collective_s']:.3e}s)")


if __name__ == "__main__":
    main()
