"""Training launcher: `python -m repro.launch.train --arch glm4-9b
[--reduced] [--steps N] ...`

On real hardware this runs the full config on the production mesh; on
CPU (this container) use --reduced for the smoke-scale config on a host
mesh. Wires: config -> model -> shardings -> fault-tolerant Trainer
(checkpoint/resume/preemption) -> metrics log.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.lm import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    batch = args.batch or (4 if args.reduced else 256)
    seq = args.seq or (64 if args.reduced else 4096)
    model = Model(cfg, remat=args.remat)
    params = model.init(jax.random.PRNGKey(args.seed))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=args.seed)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5))

    trainer = Trainer(model, dcfg, ocfg, tcfg)
    trainer.install_signal_handlers()

    def log(step, m):
        print(f"step {step:5d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
              f"{m['step_time_s']*1e3:.0f} ms")

    out = trainer.run(params, args.steps, on_metrics=log)
    print(f"done at step {out['step']}; preempted={out['preempted']}; "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
