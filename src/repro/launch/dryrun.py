"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the full
published config is lowered against ShapeDtypeStruct inputs (no
allocation), compiled for the production mesh, and the compiled
artifact's memory/cost analysis + collective schedule are recorded for
the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    python -m repro.launch.dryrun --all                  # 40-cell sweep
    python -m repro.launch.dryrun --all --multi-pod      # 512-chip mesh
"""
# The dry-run (and ONLY the dry-run) fakes 512 host devices so
# jax.make_mesh can build the production mesh. MUST precede any jax
# import (jax locks the device count on first init).
import os
if "--real-devices" not in os.sys.argv:  # noqa: E402
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, list_archs           # noqa: E402
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicable  # noqa: E402
from repro.launch import shardings as sh                    # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.model import Model                        # noqa: E402
from repro.optim import adamw                               # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.
    Returns (total_bytes, per_op_kind dict, op_count)."""
    shape_re = re.compile(r"\b(\w+)\[([\d,]*)\]")
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|\S+)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in s:
            continue  # counted at -start
        count += 1
        args = s[s.index("(", s.index(kind)):]
        for dt, dims in shape_re.findall(args):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            per_kind[kind] += n * _DTYPE_BYTES[dt]
    total = sum(per_kind.values())
    return total, per_kind, count


def build_step(model: Model, shape_name: str, mesh, variant: str = ""):
    """Returns (fn, arg_specs tuple, in_shardings tuple)."""
    cfg = model.cfg
    spec = SHAPES[shape_name]
    params_shape = model.param_specs()
    p_sh = sh.param_shardings(mesh, params_shape, variant)

    if spec.mode == "train":
        opt_shape = jax.eval_shape(adamw.adamw_init, params_shape)
        o_sh = sh.opt_shardings(mesh, opt_shape, p_sh, params_shape,
                                variant)
        batch_shape = model.input_specs(spec)
        b_sh = sh.batch_shardings(mesh, batch_shape)
        opt_cfg = adamw.AdamWConfig()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch)[0])(params)
            params, opt_state, metrics = adamw.adamw_update(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, loss
        return train_step, (params_shape, opt_shape, batch_shape), \
            (p_sh, o_sh, b_sh)

    if spec.mode == "prefill":
        batch_shape = model.input_specs(spec)
        b_sh = sh.batch_shardings(mesh, batch_shape)

        def prefill_step(params, batch):
            return model.prefill(params, batch)
        return prefill_step, (params_shape, batch_shape), (p_sh, b_sh)

    # decode: one new token against a seq_len KV cache
    batch_shape = model.input_specs(spec)
    state_shape = batch_shape.pop("state")
    s_sh = sh.decode_state_shardings(mesh, state_shape, cfg, variant)
    b_sh = sh.batch_shardings(mesh, batch_shape)

    def serve_step(params, state, batch):
        logits, new_state = model.decode_step(params, state,
                                              batch["tokens"])
        return logits, new_state
    return serve_step, (params_shape, state_shape, batch_shape), \
        (p_sh, s_sh, b_sh)


def probe_config(cfg, n_units: int):
    """A config with `n_units` repeating units (layers / zamba groups /
    enc+dec layer pairs) — used to extract per-unit cost terms."""
    import dataclasses as dc
    from repro.configs.base import MAMBA2, SHARED_ATTN
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        nl = every * n_units
        pattern = tuple(SHARED_ATTN if (i + 1) % every == 0 else MAMBA2
                        for i in range(nl))
        return dc.replace(cfg, num_layers=nl, block_pattern=pattern)
    if cfg.is_encoder_decoder:
        return dc.replace(cfg, num_layers=n_units,
                          num_encoder_layers=n_units)
    return dc.replace(cfg, num_layers=n_units)


def n_units_of(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_every
    return cfg.num_layers   # enc-dec: num_layers == num_encoder_layers


def _compile_cost(cfg, shape_name, mesh, remat, unroll, variant=""):
    """Compile one variant; return (flops, bytes, coll_total, coll_kinds,
    coll_ops, memory_analysis)."""
    from jax.sharding import PartitionSpec as P
    from repro.models import moe as moe_lib
    from repro.models import transformer as T
    T.set_scan_unroll(unroll)
    if variant == "moe_hints":
        moe_lib.set_sharding_hints({
            "dispatch": P(None, "data", None),
            "hidden": P(None, "data", "model")})
    else:
        moe_lib.set_sharding_hints(None)
    model = Model(cfg, attn_impl="blockwise",
                  remat=remat if SHAPES[shape_name].mode == "train"
                  else "none")
    fn, arg_shapes, in_sh = build_step(model, shape_name, mesh, variant)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(
            *arg_shapes).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll_total, coll_kinds, coll_ops = collective_bytes(hlo)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll_total, coll_kinds, coll_ops, mem)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             remat: str = "dots", out_dir: str = "experiments/dryrun",
             probe: bool = True, variant: str = "",
             expert_gather: bool = False, kv_bits: int = 16):
    """One (arch x shape x mesh) cell.

    The production program keeps layers under lax.scan (small HLO, fast
    compile); XLA's cost model counts a while body ONCE, so scanned
    FLOPs/bytes/collectives would be ~L x under-reported. We therefore
    compile the rolled full config for memory_analysis (that IS the
    production binary), plus two UNROLLED probes at 1 and 2 units, and
    extrapolate cost terms linearly: total = f1 + (N-1) * (f2 - f1) —
    exact for homogeneous stacks (all assigned archs are).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if expert_gather or kv_bits != 16:
        cfg = _dc.replace(cfg, hades=_dc.replace(
            cfg.hades, expert_gather_decode=expert_gather,
            kv_quant_bits=kv_bits))
    ok, why = applicable(cfg, shape_name)
    mesh_name = "pod512" if multi_pod else "pod256"
    tag = f"_{variant}" if variant else ""
    tag += "_eg" if expert_gather else ""
    tag += f"_kv{kv_bits}" if kv_bits != 16 else ""
    cell = f"{arch}_{shape_name}_{mesh_name}{tag}"
    if not ok:
        print(f"[skip] {cell}: {why}")
        return {"cell": cell, "skipped": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    # 1) rolled, full config — the production binary; memory must fit.
    (f_roll, b_roll, c_roll, ck_roll, co_roll, mem) = _compile_cost(
        cfg, shape_name, mesh, remat, unroll=False, variant=variant)

    # analytic per-device argument bytes (exact: global leaf size /
    # product of mesh-axis factors in its sharding) — params + opt state
    # + decode caches; proves the state fits HBM independent of the CPU
    # backend's (unreliable) temp accounting.
    model_full = Model(cfg)
    _, arg_shapes_full, in_sh_full = build_step(model_full, shape_name,
                                                mesh, variant)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(leaf, sharding):
        n = 1
        for s in leaf.shape:
            n *= s
        n *= jnp.dtype(leaf.dtype).itemsize
        denom = 1
        spec = getattr(sharding, "spec", None)
        if spec is not None:
            for entry in spec:
                if entry is None:
                    continue
                for ax in ((entry,) if isinstance(entry, str) else entry):
                    denom *= axis_sizes.get(ax, 1)
        return n / denom

    arg_analytic = 0.0
    for tree, shs in zip(arg_shapes_full, in_sh_full):
        leaves = jax.tree.leaves(tree)
        sh_leaves = jax.tree.leaves(shs,
                                    is_leaf=lambda x: hasattr(x, "spec"))
        for leaf, s in zip(leaves, sh_leaves):
            arg_analytic += leaf_bytes(leaf, s)

    result = {
        "cell": cell, "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "chips": n_chips,
        "variant": variant, "expert_gather": expert_gather,
        "kv_bits": kv_bits,
        "mode": SHAPES[shape_name].mode,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": SHAPES[shape_name].global_batch *
        (SHAPES[shape_name].seq_len
         if SHAPES[shape_name].mode != "decode" else 1),
        "flops_rolled": f_roll, "bytes_rolled": b_roll,
        "collective_bytes_rolled": c_roll,
        "arg_bytes_per_device_analytic": arg_analytic,
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            result[attr] = int(v)

    # 2) unrolled probes at 1 and 2 units -> linear extrapolation.
    if probe:
        nu = n_units_of(cfg)
        f1, b1, c1, k1, o1, _ = _compile_cost(
            probe_config(cfg, 1), shape_name, mesh, remat, unroll=True,
            variant=variant)
        f2, b2, c2, k2, o2, _ = _compile_cost(
            probe_config(cfg, 2), shape_name, mesh, remat, unroll=True,
            variant=variant)
        result.update(
            n_units=nu,
            flops=f1 + (nu - 1) * (f2 - f1),
            bytes_accessed=b1 + (nu - 1) * (b2 - b1),
            collective_bytes=c1 + (nu - 1) * (c2 - c1),
            collective_ops=o1 + (nu - 1) * (o2 - o1),
            collective_kinds={k: k1[k] + (nu - 1) * (k2[k] - k1[k])
                              for k in k1},
            probe={"f1": f1, "f2": f2, "b1": b1, "b2": b2,
                   "c1": c1, "c2": c2})
    result["compile_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    msg = (f"[ok] {cell}: compile {result['compile_s']}s, "
           f"args ~{arg_analytic/2**30:.2f} GiB/dev")
    if probe:
        msg += (f", flops {result['flops']:.3e}, "
                f"bytes {result['bytes_accessed']:.3e}, "
                f"coll {result['collective_bytes']:.3e}")
    print(msg)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="")
    ap.add_argument("--expert-gather", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=16)
    ap.add_argument("--real-devices", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = SHAPE_ORDER if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                try:
                    # probes (cost extrapolation) only feed the roofline,
                    # which is single-pod; multi-pod is the shard-proof.
                    run_cell(arch, shp, multi_pod=mp, remat=args.remat,
                             out_dir=args.out, probe=not mp,
                             variant=args.variant,
                             expert_gather=args.expert_gather,
                             kv_bits=args.kv_bits)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shp, mp, repr(e)))
                    print(f"[FAIL] {arch} {shp} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall cells compiled.")


if __name__ == "__main__":
    main()
