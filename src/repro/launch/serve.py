"""Serving launcher: `python -m repro.launch.serve --arch glm4-9b
--reduced --requests 8` — batched decode with the HADES-managed paged KV
cache (runtime/server.py), reporting KV RSS + collector activity.

`--mode generate` (default) teacher-forces one fixed batch through
`Server.generate`; `--mode serve` drives the continuous-batching queue
(`Server.serve`): more requests than lanes, lane churn at one dispatch
per window, per-window RSS-vs-live gauges. `--temperature/--top-k`
switch on in-scan sampling (a PRNG key is derived from --seed).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import backend as be
from repro.models.model import Model
from repro.runtime.server import Request, Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="generate",
                    choices=("generate", "serve"),
                    help="fixed-batch generate or continuous-batching "
                         "queue serving")
    ap.add_argument("--requests", type=int, default=4,
                    help="batch lanes (generate) / queued requests "
                         "(serve)")
    ap.add_argument("--lanes", type=int, default=0,
                    help="serve mode: batch lanes (0 -> min(requests, 4))")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 samples in-scan (greedy otherwise)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter for sampled decode (0 = full "
                         "vocab)")
    ap.add_argument("--backend", default="proactive", choices=be.names(),
                    help="tiering backend (backend registry)")
    ap.add_argument("--hbm-target-mb", type=int, default=0,
                    help="pressure target / promote high watermark for "
                         "the reactive/cap/mglru/promote backends")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    be_params = be.pressure_params(args.backend, args.hbm_target_mb << 20)
    if args.hbm_target_mb and not be_params:
        ap.error(f"--hbm-target-mb is not applicable to {args.backend!r}"
                 " (it declares no pressure field)")

    cfg = get_config(args.arch, reduced=args.reduced)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    lanes = args.requests if args.mode == "generate" else \
        (args.lanes or min(args.requests, 4))
    srv = Server(model, ServerConfig(
        batch=lanes, max_len=args.max_len,
        block_tokens=max(args.max_len // 16, 4), backend=args.backend,
        backend_params=be_params, temperature=args.temperature,
        top_k=args.top_k))
    rng = np.random.default_rng(args.seed)
    sample_key = jax.random.PRNGKey(args.seed + 1)

    if args.mode == "generate":
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size,
                         (args.requests, args.prompt_len)), jnp.int32)
        greedy = args.temperature <= 0
        out = srv.generate(params, prompts, max_new=args.max_new,
                           greedy=greedy,
                           key=None if greedy else sample_key)
        print(f"generated {out.shape} tokens; "
              f"KV RSS {srv.kv_rss_bytes()/2**20:.2f} MiB")
    else:
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                            (args.prompt_len,)).tolist(),
                        max_new=args.max_new,
                        temperature=args.temperature, top_k=args.top_k)
                for _ in range(args.requests)]
        key = sample_key if args.temperature > 0 else None
        results = srv.serve(params, reqs, key=key)
        n_windows = len(srv.serve_log)
        print(f"served {len(results)} requests on {lanes} lanes in "
              f"{n_windows} windows ({srv.dispatches} dispatches); "
              f"{sum(len(r.tokens) for r in results)} tokens")
        peak = max((e["rss_bytes"] for e in srv.serve_log), default=0.0)
        print(f"KV RSS peak {peak/2**20:.2f} MiB -> final "
              f"{srv.kv_rss_bytes()/2**20:.2f} MiB "
              f"(reclaimed after finishes)")
    for r in srv.reports[-3:]:
        print("  collector:", {k: round(v, 4) for k, v in r.items()})


if __name__ == "__main__":
    main()
