"""Serving launcher: `python -m repro.launch.serve --arch glm4-9b
--reduced --requests 8` — batched decode with the HADES-managed paged KV
cache (runtime/server.py), reporting KV RSS + collector activity.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import backend as be
from repro.models.model import Model
from repro.runtime.server import Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--backend", default="proactive", choices=be.names(),
                    help="tiering backend (backend registry)")
    ap.add_argument("--hbm-target-mb", type=int, default=0,
                    help="pressure target / promote high watermark for "
                         "the reactive/cap/mglru/promote backends")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    be_params = be.pressure_params(args.backend, args.hbm_target_mb << 20)
    if args.hbm_target_mb and not be_params:
        ap.error(f"--hbm-target-mb is not applicable to {args.backend!r}"
                 " (it declares no pressure field)")

    cfg = get_config(args.arch, reduced=args.reduced)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    srv = Server(model, ServerConfig(
        batch=args.requests, max_len=args.max_len,
        block_tokens=max(args.max_len // 16, 4), backend=args.backend,
        backend_params=be_params))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)),
        jnp.int32)
    out = srv.generate(params, prompts, max_new=args.max_new)
    print(f"generated {out.shape} tokens; "
          f"KV RSS {srv.kv_rss_bytes()/2**20:.2f} MiB")
    for r in srv.reports[-3:]:
        print("  collector:", {k: round(v, 4) for k, v in r.items()})


if __name__ == "__main__":
    main()
