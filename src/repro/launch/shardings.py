"""Sharding rules: param / optimizer / batch / decode-state PartitionSpecs
for every architecture, derived from leaf paths + shapes.

Scheme (DESIGN.md §3.3):
  * 2-D weight sharding = FSDP over "data" x TP over "model". Every large
    matrix shards its TP axis (heads / d_ff / experts / vocab) over
    "model" and its other big axis over "data" (ZeRO-3-style); XLA SPMD
    inserts the all-gathers. Tensors whose dims don't divide are left
    replicated on that axis (MQA kv projections, tiny norms).
  * The "pod" axis carries pure data parallelism: params are NOT sharded
    over pods (cross-pod all-gathers would cross DCI); the batch is.
  * Decode KV caches shard batch over "data" and cache length over
    "model" (kv-head counts rarely divide 16; sequence does) — softmax
    over the sharded length lowers to a psum, flash-decoding style.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _div(n: int, size: int) -> bool:
    return n > 0 and size > 0 and n % size == 0


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(mesh.shape).get(name, 1)   # works for AbstractMesh too


def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...],
               variant: str = "") -> P:
    """PartitionSpec for one parameter leaf. Paths look like
    layers/wq, layers/moe/wi, mamba/m/in_proj, embed, out, ...
    Stacked-per-layer leaves have a leading L dim (never sharded).

    §Perf variants:
      "moe_zero"  — MoE expert weights TP-only on F (contraction dim
                    unsharded -> no activation-sized partial-sum
                    all-reduces); optimizer state stays 2-D (ZeRO).
      "serve_tp"  — decode-only: 256-way TP over ("data","model") on
                    every output dim (batch≈1 leaves "data" idle).
    """
    dsz = _axis_size(mesh, "data")
    msz = _axis_size(mesh, "model")
    name = path.split("/")[-1]
    # drop leading stacked-layer dims (layers are scanned): we only shard
    # the trailing matrix dims
    nd = len(shape)

    def spec(*trailing):
        return P(*([None] * (nd - len(trailing)) + list(trailing)))

    if variant == "serve_tp":
        both = dsz * msz

        def tp(out_axis_last: bool):
            a, b = shape[-2:]
            out, other = (b, a) if out_axis_last else (a, b)
            if out % both == 0:
                e = ("data", "model")
            elif out % msz == 0:
                e = "model"
            else:
                return P()
            return spec(None, e) if out_axis_last else spec(e, None)

        if name in ("wq", "wk", "wv", "xq", "xv", "xk", "wi", "wg",
                    "in_proj", "x_proj", "dt_proj"):
            if "moe" in path:
                _, d, f = shape[-3:]
                if f % both == 0:
                    return spec(None, None, ("data", "model"))
                return spec(None, None,
                            "model" if f % msz == 0 else None)
            return tp(out_axis_last=True)
        if name in ("wo", "xo", "out_proj"):
            if "moe" in path:
                _, f, d = shape[-3:]
                if f % both == 0:
                    return spec(None, ("data", "model"), None)
                return spec(None,
                            "model" if f % msz == 0 else None, None)
            return tp(out_axis_last=False)
        if name == "embed":
            v, d = shape
            return P(("data", "model") if v % both == 0 else
                     ("model" if v % msz == 0 else None), None)
        if name == "out":
            d, v = shape
            return P(None, ("data", "model") if v % both == 0 else
                     ("model" if v % msz == 0 else None))
        return P()

    if variant == "moe_zero" and "moe" in path:
        if name in ("wi", "wg"):
            e, d, f = shape[-3:]
            return spec(None, None, "model" if _div(f, msz) else None)
        if name == "wo":
            e, f, d = shape[-3:]
            return spec(None, "model" if _div(f, msz) else None, None)

    if name in ("ln", "ln1", "ln2", "ln_x", "final_ln", "enc_ln", "norm",
                "conv_b", "dt_bias", "D", "A_log", "conv_w"):
        return P()
    if name == "router":
        return P()
    if name in ("embed",):
        v, d = shape
        return P("model" if _div(v, msz) else None,
                 "data" if _div(d, dsz) else None)
    if name == "out":
        d, v = shape
        return P("data" if _div(d, dsz) else None,
                 "model" if _div(v, msz) else None)
    if name in ("wq", "wk", "wv", "xq", "xk", "xv"):
        d, e = shape[-2:]
        return spec("data" if _div(d, dsz) else None,
                    "model" if _div(e, msz) else None)
    if name in ("wo", "xo") and nd >= 2 and "moe" not in path:
        e, d = shape[-2:]
        return spec("model" if _div(e, msz) else None,
                    "data" if _div(d, dsz) else None)
    if "moe" in path and name in ("wi", "wg"):
        e, d, f = shape[-3:]
        if _div(e, msz):
            return spec("model", "data" if _div(d, dsz) else None, None)
        return spec(None, "data" if _div(d, dsz) else None,
                    "model" if _div(f, msz) else None)
    if "moe" in path and name == "wo":
        e, f, d = shape[-3:]
        if _div(e, msz):
            return spec("model", None, "data" if _div(d, dsz) else None)
        return spec(None, "model" if _div(f, msz) else None,
                    "data" if _div(d, dsz) else None)
    if name in ("wi", "wg"):                      # dense ffn
        d, f = shape[-2:]
        return spec("data" if _div(d, dsz) else None,
                    "model" if _div(f, msz) else None)
    if name == "wo":                              # dense ffn out
        f, d = shape[-2:]
        return spec("model" if _div(f, msz) else None,
                    "data" if _div(d, dsz) else None)
    if name in ("in_proj", "x_proj", "dt_proj", "out_proj"):
        a, b = shape[-2:]
        return spec("data" if _div(a, dsz) else None,
                    "model" if _div(b, msz) else None)
    return P()


def param_shardings(mesh: Mesh, params_shape: Any,
                    variant: str = "") -> Any:
    """NamedSharding tree matching a params shape tree (eval_shape out)."""
    def one(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return NamedSharding(mesh, param_spec(mesh, pstr, leaf.shape,
                                              variant))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(mesh: Mesh, opt_shape: Any, params_sh: Any,
                  params_shape: Any = None, variant: str = "") -> Any:
    """Optimizer m/v inherit the param shardings; step is replicated.
    Under "moe_zero" m/v keep the BASELINE 2-D shards (ZeRO: the update
    resharding is a weights-sized reduce-scatter/all-gather instead of
    activation-sized partial-sum all-reduces)."""
    mv_sh = params_sh
    if variant == "moe_zero" and params_shape is not None:
        mv_sh = param_shardings(mesh, params_shape, variant="")
    return {
        "m": mv_sh, "v": mv_sh,
        "step": NamedSharding(mesh, P()),
    }


def batch_spec(mesh: Mesh, ndim: int) -> P:
    """Batch arrays: leading dim over (pod, data)."""
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(axes, *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, batch_shape: Any) -> Any:
    def one(leaf):
        dims = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = dims.get("pod", 1) * dims.get("data", 1)
        if leaf.ndim >= 1 and leaf.shape[0] % total == 0:
            return NamedSharding(mesh, batch_spec(mesh, leaf.ndim))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch_shape)


def decode_state_shardings(mesh: Mesh, state_shape: Any,
                           cfg: ModelConfig, variant: str = "") -> Any:
    """KV caches [L, B, C, KV, D]: B->data, C->model. SSM states
    [L, B, ...]: B->data. pos/enc replicated/batch-sharded.
    "serve_tp": cache length shards over BOTH axes (idle batch)."""
    dsz = _axis_size(mesh, "data")
    msz = _axis_size(mesh, "model")

    def c_axis(b, c):
        if variant == "serve_tp" and _div(c, dsz * msz):
            return ("data", "model")
        return "model" if _div(c, msz) else None

    def one(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        name = pstr.split("/")[-1]
        if name in ("k", "v"):
            l, b, c, kv, d = leaf.shape
            return NamedSharding(mesh, P(
                None, "data" if _div(b, dsz) and variant != "serve_tp"
                else None, c_axis(b, c), None, None))
        if name == "k_pos":
            l, b, c = leaf.shape
            return NamedSharding(mesh, P(
                None, "data" if _div(b, dsz) and variant != "serve_tp"
                else None, c_axis(b, c)))
        if name in ("h", "conv"):
            b_axis = 1 if leaf.ndim >= 3 else 0
            spec = [None] * leaf.ndim
            if _div(leaf.shape[b_axis], dsz):
                spec[b_axis] = "data"
            # zamba2 stacks states [groups, per, B, ...]
            if leaf.ndim >= 4 and not _div(leaf.shape[1], dsz) and \
                    _div(leaf.shape[2], dsz):
                spec = [None] * leaf.ndim
                spec[2] = "data"
            return NamedSharding(mesh, P(*spec))
        if name == "enc_out":
            b = leaf.shape[0]
            return NamedSharding(mesh, P(
                "data" if _div(b, dsz) else None))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, state_shape)
