"""Pallas TPU kernel: `mamba_scan` — the selective-SSM recurrence
h_t = a_t * h_{t-1} + b_t (falcon-mamba / zamba2 hot loop).

Grid: (batch, channel_tiles, seq_chunks) — the sequence axis is the
innermost (sequential) grid dimension, so the carry state h lives in VMEM
scratch across chunk steps. Each step loads an [chunk, CT, N] tile of
(a, b), runs the recurrence with an unrolled fori_loop (elementwise VPU
work — no MXU here, this kernel is bandwidth-bound), and streams out the
same-shaped h tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref, h_scr, *,
            chunk: int, n_chunks: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[0].astype(jnp.float32)   # [chunk, CT, N]
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h
    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(s == n_chunks - 1)
    def _finish():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def mamba_scan_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                      chunk: int = 64, ct: int = 8,
                      interpret: bool = True):
    """a, b: [B, S, C, N] (N % 128 == 0 after wrapper padding);
    h0: [B, C, N]. Returns (h_all [B,S,C,N] fp32, h_last [B,C,N] fp32)."""
    bsz, s, c, n = a.shape
    chunk = min(chunk, s)
    ct = min(ct, c)
    assert s % chunk == 0 and c % ct == 0
    n_chunks = s // chunk
    grid = (bsz, c // ct, n_chunks)
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, ct, n), lambda i, j, sc: (i, sc, j, 0)),
            pl.BlockSpec((1, chunk, ct, n), lambda i, j, sc: (i, sc, j, 0)),
            pl.BlockSpec((1, ct, n), lambda i, j, sc: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, ct, n), lambda i, j, sc: (i, sc, j, 0)),
            pl.BlockSpec((1, ct, n), lambda i, j, sc: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, c, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, c, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ct, n), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
