"""Pallas TPU kernel: `paged_attention` — decode through the object table.

The HADES serving hot loop: one query token per sequence attends over a
KV cache whose blocks live in HadesPool slots. The block table (logical
block -> physical slot) is *scalar-prefetched*, so each grid step's KV
block DMA is issued from the indirection without a gather materializing;
the online-softmax runs in VMEM scratch.

The paper's access-bit recording is FUSED: the kernel emits one touched
bit per (sequence, block) as a by-product of the DMA it already did —
this is how tracking overhead stays at "4-5 ns / skip-if-set" (§4): the
tracking rides the read.

GQA layout: q is [B, KV, REP, D] (q heads grouped by kv head); each grid
step contracts the [bt, D] block against all REP q-heads of its kv head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, touched_ref,
            m_scr, l_scr, acc_scr, *, block_tokens: int, n_blocks: int,
            scale: float):
    b = pl.program_id(0)
    kvh = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [REP, D]
    k = k_ref[0, :, 0].astype(jnp.float32)            # [bt, D]
    v = v_ref[0, :, 0].astype(jnp.float32)            # [bt, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [REP, bt]

    # validity: token position within seq_len AND block mapped
    pos = j * block_tokens + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    valid = (pos < lens_ref[b]) & (bt_ref[b, j] >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale_prev = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * scale_prev + jnp.sum(p, -1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * scale_prev + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # fused access-bit recording: this block was dereferenced
    was_used = (j * block_tokens < lens_ref[b]) & (bt_ref[b, j] >= 0)
    touched_ref[0, 0] = was_used.astype(jnp.int32)

    @pl.when(j == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           seq_lens: jax.Array, *, scale: float = None,
                           interpret: bool = True):
    """q: [B, KV, REP, D]; k_pages/v_pages: [n_slots, bt, KV, D];
    block_tables: [B, MB] int32 physical slot ids (-1 unused);
    seq_lens: [B] int32.
    Returns (out [B, KV, REP, D], touched [B, MB] int32)."""
    b, kv, rep, d = q.shape
    n_slots, bt, kv2, d2 = k_pages.shape
    assert (kv, d) == (kv2, d2)
    mb = block_tables.shape[1]
    safe_tables = jnp.where(block_tables >= 0, block_tables, 0) \
        .astype(jnp.int32)

    grid = (b, kv, mb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # block_tables, seq_lens
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda i, h, j, tbl, lens: (i, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, d),
                         lambda i, h, j, tbl, lens: (tbl[i, j], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, d),
                         lambda i, h, j, tbl, lens: (tbl[i, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda i, h, j, tbl, lens: (i, h, 0, 0)),
            pl.BlockSpec((1, 1),
                         lambda i, h, j, tbl, lens: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    kern = functools.partial(
        _kernel, block_tokens=bt, n_blocks=mb,
        scale=scale if scale is not None else d ** -0.5)
    out, touched = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
            jax.ShapeDtypeStruct((b, mb), jnp.int32),
        ],
        interpret=interpret,
    )(safe_tables, seq_lens.astype(jnp.int32), q, k_pages, v_pages)
    return out, touched
