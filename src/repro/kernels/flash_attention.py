"""Pallas TPU kernel: `flash_attention` — tiled online-softmax attention
(training / prefill), causal with optional sliding window.

Grid: (batch*heads, Sq/BQ, Sk/BK) — the KV axis is innermost so the
(m, l, acc) accumulators live in VMEM scratch across KV steps and the
output tile is written once on the last step. Block shapes are
MXU-aligned (128 multiples); softmax runs in fp32, output is cast back.
GQA is handled in ops.py by mapping each q-head group to its kv head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_k_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale        # [BQ, D]
    k = k_ref[0].astype(jnp.float32)                # [BK, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [BQ, BK]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # [BQ, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [BQ, BK]
    scale_prev = jnp.exp(m_prev - m_new)             # [BQ, 1]
    l_scr[...] = l_scr[...] * scale_prev + jnp.sum(p, -1, keepdims=True)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * scale_prev + pv

    @pl.when(ki == n_k_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           scale: float = None,
                           interpret: bool = True) -> jax.Array:
    """q/k/v: [BH, S, D] (one kv head per q head — GQA expanded by the
    wrapper). D and S must be 128-multiples (wrapper pads); `scale` is
    the softmax scale of the UNPADDED head dim."""
    bh, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0 and d % 128 == 0
    n_k = s // bk
    grid = (bh, s // bq, n_k)
    kern = functools.partial(
        _kernel, scale=scale if scale is not None else d ** -0.5,
        causal=causal, window=window, bq=bq, bk=bk, n_k_steps=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
