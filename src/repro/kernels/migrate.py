"""Pallas TPU kernel: `migrate` — the Object Collector's data mover.

The paper's hot loop when tidying: copy object payloads from their old
slots to their new (dense) slots. On TPU this is a batched indirection
copy through VMEM: move indices are *scalar-prefetched* so the index math
runs ahead of the data DMAs (PrefetchScalarGridSpec), each grid step
streams one [1, W_TILE] tile HBM->VMEM->HBM, and the pool array is
aliased in/out so unmoved slots cost nothing.

In-place safety contract (enforced by callers — ops.migrate routes
masked-out moves to a scratch row to honor it): grid steps run in
ascending move order and READ THE PRE-KERNEL VALUE of their source, so
no move may read a slot a previous move overwrote. Sufficient
conditions: (a) src and dst slot sets are disjoint (cross-heap
migration: dst slots are free), or (b) moves are sorted so
dst[i] <= src[i] (left-packing compaction). A self-move (src == dst)
is NOT automatically safe: if its slot is an earlier move's
destination, it rewrites stale bytes over the fresh copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128  # TPU lane width; slot payload is padded to a multiple


def _kernel(idx_ref, data_ref, out_ref):
    # idx_ref is the scalar-prefetch ref (unused in the body: the gather/
    # scatter happens in the index_maps); the body is a pure VMEM copy.
    out_ref[...] = data_ref[...]


def migrate_pallas(data: jax.Array, src: jax.Array, dst: jax.Array,
                   *, w_tile: int = 512, interpret: bool = True
                   ) -> jax.Array:
    """data: [n_slots, W] (W % 128 == 0), src/dst: [n_moves] int32.
    Returns data with data[dst[i]] = data[src[i]] applied in move order;
    each move reads its source's PRE-kernel value (see the module
    docstring for the aliasing contract).
    """
    n_slots, w = data.shape
    n_moves = src.shape[0]
    assert w % LANE == 0, f"slot width {w} not lane-aligned"
    w_tile = min(w_tile, w)
    assert w % w_tile == 0
    idx = jnp.stack([src, dst], axis=0).astype(jnp.int32)  # [2, n_moves]

    grid = (n_moves, w // w_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, w_tile),
                               lambda i, j, idx: (idx[0, i], j))],
        out_specs=pl.BlockSpec((1, w_tile),
                               lambda i, j, idx: (idx[1, i], j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(data.shape, data.dtype),
        input_output_aliases={1: 0},   # pool array aliased in/out
        interpret=interpret,
    )
    return fn(idx, data)
