"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernel
shape/dtype sweeps assert against)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import object_table as ot
from repro.models import attention as attn_lib


# ---------------------------------------------------------------------------
# migrate — the Object Collector's data mover
# ---------------------------------------------------------------------------
def migrate(data: jax.Array, src: jax.Array, dst: jax.Array,
            ok: jax.Array) -> jax.Array:
    """Copy data[src[i]] -> data[dst[i]] where ok[i] (batched indirection
    copy over [n_slots, slot_words])."""
    n_slots = data.shape[0]
    return data.at[jnp.where(ok, dst, n_slots)].set(
        data[src], mode="drop")


# ---------------------------------------------------------------------------
# access_scan — collector bitmap scan + CIW update + per-sb histogram
# ---------------------------------------------------------------------------
def access_scan(table: jax.Array, ciw_threshold: jax.Array, sb_slots: int,
                n_sbs: int) -> Tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
    """One pass over packed table words.
    Returns (new_table [N] with CIW updated,
             to_hot [N] bool, to_cold [N] bool,
             sb_hot_hist [n_sbs] int32 — accessed-object count per
             superblock of the object's *current* slot,
             skipped_atc [] int32 — live objects the classifier wanted to
             act on but the ATC lock-free rule vetoed this pass)."""
    live = ot.is_live(table)
    acc = (ot.access_of(table) == 1) & live
    atc = ot.atc_of(table)
    heap = ot.heap_of(table)
    ciw = ot.ciw_of(table)
    ciw = jnp.where(acc, 0, jnp.minimum(ciw + 1, ot.CIW_SAT))
    ciw = jnp.where(live, ciw, 0)
    ct = ciw_threshold.astype(jnp.uint32)
    movable = live & (atc == 0)
    to_hot = acc & ((heap == ot.NEW) | (heap == ot.COLD)) & movable
    to_cold = (~acc) & (ciw > ct) & ((heap == ot.NEW) | (heap == ot.HOT)) \
        & movable
    new_table = (table & ~(ot.CIW_MASK << ot.CIW_SHIFT)) | \
        (ciw.astype(jnp.uint32) << ot.CIW_SHIFT)
    sb = (ot.slot_of(table) // sb_slots).astype(jnp.int32)
    hist = jnp.zeros((n_sbs,), jnp.int32).at[
        jnp.where(acc, sb, n_sbs)].add(1, mode="drop")
    skipped = jnp.sum(live & (atc > 0) &
                      (acc | ((ciw > ct) & (heap != ot.COLD)))
                      ).astype(jnp.int32)
    return new_table, to_hot, to_cold, hist, skipped


# ---------------------------------------------------------------------------
# flash_attention — training attention (causal, optional sliding window)
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B,S,H,D], k/v: [B,S,KV,D] -> [B,S,H,D]."""
    return attn_lib.full_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# paged_attention — decode through the object table (block-paged KV)
# ---------------------------------------------------------------------------
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array,
                    block_tokens: int) -> Tuple[jax.Array, jax.Array]:
    """q: [B,H,D] one token per sequence.
    k_pages/v_pages: [n_slots, block_tokens, KV, D] — the HadesPool data.
    block_tables: [B, max_blocks] physical slot per logical KV block
    (-1 = unused). seq_lens: [B].
    Returns (out [B,H,D], touched [B, max_blocks] bool — the access bits
    the fused tracking would record)."""
    b, h, d = q.shape
    n_slots, bt, kv, _ = k_pages.shape
    mb = block_tables.shape[1]
    n_rep = h // kv
    safe = jnp.maximum(block_tables, 0)
    k = k_pages[safe]                       # [B, mb, bt, KV, D]
    v = v_pages[safe]
    k = k.reshape(b, mb * bt, kv, d)
    v = v.reshape(b, mb * bt, kv, d)
    pos = jnp.arange(mb * bt)[None]
    valid = (pos < seq_lens[:, None]) & \
        (jnp.repeat(block_tables >= 0, bt, axis=1))
    out, m, l = attn_lib.decode_attention_partial(
        q[:, None], k, v, valid)
    out = out / jnp.moveaxis(jnp.maximum(l, 1e-30), 1, -1)[..., None]
    n_blocks_used = (seq_lens + block_tokens - 1) // block_tokens
    touched = (jnp.arange(mb)[None] < n_blocks_used[:, None]) & \
        (block_tables >= 0)
    return out[:, 0].astype(q.dtype), touched


# ---------------------------------------------------------------------------
# mamba_scan — selective-SSM recurrence h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------
def mamba_scan(a: jax.Array, b: jax.Array, h0: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """a, b: [B, S, C, N]; h0: [B, C, N] -> (h_all [B,S,C,N], h_last)."""
    def step(h, xs):
        ai, bi = xs
        h = ai * h + bi
        return h, h
    h_last, h_all = jax.lax.scan(
        step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(h_all, 0, 1), h_last
