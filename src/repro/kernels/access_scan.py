"""Pallas TPU kernel: `access_scan` — the Object Collector's table sweep.

One memory-bound pass over the packed object-table words (paper §4: the
collector "periodically scans a sparse bitmap"): unpack access/heap/ATC
bits, update the CIW lanes, emit migration candidate masks, and build the
per-superblock hot-object histogram the backends consume.

TPU shape: the table is viewed as [rows, 128] uint32 lanes; the histogram
is accumulated MXU-style — a one-hot [tile, n_sbs] matrix contracted with
the access vector per tile — because scatter-add is not a TPU-native
primitive but matmul accumulation is.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import object_table as ot

LANE = 128

# python-int copies of the packing constants (Pallas kernel bodies must
# not capture traced jnp constants)
_SLOT_MASK = (1 << ot.SLOT_BITS) - 1
_HEAP_MASK = (1 << ot.HEAP_BITS) - 1
_ATC_MASK = (1 << ot.ATC_BITS) - 1
_CIW_MASK = (1 << ot.CIW_BITS) - 1


def _kernel(ct_ref, sbslots_ref, table_ref, new_table_ref, to_hot_ref,
            to_cold_ref, hist_ref, skipped_ref, *, with_hist: bool):
    i = pl.program_id(0)
    w = table_ref[...]                       # [rows_tile, 128] uint32
    live = ((w >> ot.HEAP_SHIFT) & _HEAP_MASK) != ot.FREE
    acc = (((w >> ot.ACCESS_SHIFT) & 1) == 1) & live
    atc = (w >> ot.ATC_SHIFT) & _ATC_MASK
    heap = (w >> ot.HEAP_SHIFT) & _HEAP_MASK
    ciw = (w >> ot.CIW_SHIFT) & _CIW_MASK
    ciw = jnp.where(acc, jnp.uint32(0),
                    jnp.minimum(ciw + 1, jnp.uint32(ot.CIW_SAT)))
    ciw = jnp.where(live, ciw, jnp.uint32(0))

    ct = ct_ref[0]
    movable = live & (atc == 0)
    to_hot = acc & ((heap == ot.NEW) | (heap == ot.COLD)) & movable
    to_cold = (~acc) & (ciw > ct) & ((heap == ot.NEW) | (heap == ot.HOT)) \
        & movable

    new_table_ref[...] = (w & ~jnp.uint32(_CIW_MASK << ot.CIW_SHIFT)) | \
        (ciw << ot.CIW_SHIFT)
    to_hot_ref[...] = to_hot.astype(jnp.int32)
    to_cold_ref[...] = to_cold.astype(jnp.int32)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        skipped_ref[...] = jnp.zeros_like(skipped_ref)

    # ATC-vetoed diagnostic, accumulated across tiles: objects the Fig. 5
    # machine wanted to act on (accessed, or idle past the threshold and
    # not already COLD) that the lock-free rule skipped this pass. Folded
    # into the sweep so the collector never re-reads table fields in jnp.
    skipped = live & (atc > 0) & \
        (acc | ((ciw > ct) & (heap != ot.COLD)))
    skipped_ref[...] += jnp.sum(skipped.astype(jnp.int32)).reshape(1, 1)

    if with_hist:
        # per-superblock hot histogram via one-hot contraction
        # (MXU-friendly); statically skipped when the caller discards it
        # (the collector recomputes referenced bits post-migration)
        n_sbs = hist_ref.shape[-1]
        sb = ((w >> ot.SLOT_SHIFT) & _SLOT_MASK) // sbslots_ref[0]
        flat_sb = sb.reshape(-1).astype(jnp.int32)          # [tile]
        flat_acc = acc.reshape(-1).astype(jnp.float32)      # [tile]
        onehot = (flat_sb[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32,
                                           (flat_sb.shape[0], n_sbs),
                                           1)).astype(jnp.float32)
        contrib = jnp.dot(flat_acc[None, :], onehot,
                          preferred_element_type=jnp.float32)  # [1, n_sbs]
        hist_ref[...] += contrib.astype(jnp.int32)


def access_scan_pallas(table: jax.Array, ciw_threshold: jax.Array,
                       sb_slots: int, n_sbs: int, *, rows_tile: int = 64,
                       with_hist: bool = True, interpret: bool = True):
    """table: [N] uint32 (N % 128 == 0). Returns (new_table [N],
    to_hot [N] int32, to_cold [N] int32, hist [n_sbs] int32,
    skipped_atc [] int32; hist is all-zero when with_hist=False — the
    contraction is statically skipped)."""
    n = table.shape[0]
    assert n % LANE == 0, f"table len {n} not lane-aligned"
    rows = n // LANE
    rows_tile = min(rows_tile, rows)
    assert rows % rows_tile == 0
    t2 = table.reshape(rows, LANE)
    ct = jnp.reshape(ciw_threshold.astype(jnp.uint32), (1,))
    sbs = jnp.full((1,), sb_slots, jnp.uint32)

    grid = (rows // rows_tile,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_tile, LANE), lambda i, ct, sbs: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows_tile, LANE), lambda i, ct, sbs: (i, 0)),
            pl.BlockSpec((rows_tile, LANE), lambda i, ct, sbs: (i, 0)),
            pl.BlockSpec((rows_tile, LANE), lambda i, ct, sbs: (i, 0)),
            pl.BlockSpec((1, n_sbs), lambda i, ct, sbs: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, ct, sbs: (0, 0)),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, with_hist=with_hist),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.uint32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((1, n_sbs), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )
    new_t, to_hot, to_cold, hist, skipped = fn(ct, sbs, t2)
    return (new_t.reshape(n), to_hot.reshape(n), to_cold.reshape(n),
            hist[0], skipped[0, 0])
