"""Public jit'd wrappers for the Pallas kernels.

Each wrapper: validates/normalizes shapes (lane padding, GQA expansion),
selects interpret mode (Pallas kernels execute in interpret mode on CPU —
this container — and compile natively on TPU), and matches the ref.py
oracle bit-for-bit on the unpadded region.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import access_scan as _scan
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import migrate as _mig
from repro.kernels import paged_attention as _pa

LANE = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (grid tiles must divide the
    padded extent for any pool geometry)."""
    t = min(want, n)
    while n % t:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# migrate
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("w_tile", "has_scratch_row"))
def migrate(data: jax.Array, src: jax.Array, dst: jax.Array,
            ok: jax.Array, *, w_tile: int = 512,
            has_scratch_row: bool = False) -> jax.Array:
    """data: [n_slots(+1), W]; src/dst/ok: [n_moves]. Caller contract for
    the ACTIVE moves: disjoint src/dst sets OR left-packing order (see
    migrate.py). Masked moves (ok=False) are routed to a scratch row —
    NOT turned into self-copies, because a masked entry's slot may be an
    earlier move's destination, and a grid step reads the pre-kernel
    value (re-writing stale bytes over the fresh copy).

    `has_scratch_row=True` declares that the caller's pool layout already
    carries a permanent scratch row as data's LAST row (core/pool.py) —
    masked moves copy that row onto itself (a no-op for its all-zero
    invariant) and NO whole-pool pad copy happens; on TPU with
    lane-aligned slot widths the kernel aliases the pool in place. With
    False (standalone use, kernel sweeps) a scratch row is appended,
    which costs one pool copy per call."""
    n, w = data.shape
    if has_scratch_row:
        scratch = jnp.int32(n - 1)
        padded = _pad_to(data, LANE, 1)
    else:
        scratch = jnp.int32(n)
        # one pad covers both the lane alignment and the scratch row (a
        # second concatenate would copy the whole pool again)
        padded = jnp.pad(data, ((0, 1), (0, (-w) % LANE)))
    src_eff = jnp.where(ok, src, scratch).astype(jnp.int32)
    dst_eff = jnp.where(ok, dst, scratch).astype(jnp.int32)
    out = _mig.migrate_pallas(padded, src_eff, dst_eff,
                              w_tile=_tile(padded.shape[1], w_tile),
                              interpret=_interpret())
    return out[:n, :w]


# ---------------------------------------------------------------------------
# access_scan
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("sb_slots", "n_sbs",
                                             "with_hist"))
def access_scan(table: jax.Array, ciw_threshold: jax.Array, *,
                sb_slots: int, n_sbs: int, with_hist: bool = True):
    """table: [N] uint32. Returns (new_table, to_hot bool, to_cold bool,
    hist [n_sbs] int32 — zeros when with_hist=False, which statically
    skips the one-hot contraction for callers that discard it,
    skipped_atc [] int32 — the ATC-vetoed count, folded into the sweep so
    the collector's use_pallas path never re-reads table fields)."""
    n = table.shape[0]
    padded = _pad_to(table, LANE, axis=0)  # pad words are FREE=0b? pad=0
    # pad words decode as heap=NEW,slot=0,access=0 -> not live? heap 0 is
    # NEW; guard: set pad words to FREE so they never classify.
    if padded.shape[0] != n:
        from repro.core import object_table as ot
        pad_word = ot.free_word()
        padded = padded.at[n:].set(pad_word)
    new_t, to_hot, to_cold, hist, skipped = _scan.access_scan_pallas(
        padded, ciw_threshold, sb_slots, n_sbs,
        rows_tile=_tile(padded.shape[0] // LANE, 64),
        with_hist=with_hist, interpret=_interpret())
    return (new_t[:n], to_hot[:n].astype(bool), to_cold[:n].astype(bool),
            hist, skipped)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """q: [B,S,H,D]; k/v: [B,S,KV,D] -> [B,S,H,D]. GQA expanded here;
    D padded to 128 lanes; S must divide by the block sizes (bq/bk are
    clipped to S)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    # expand kv heads to q heads, fold heads into batch
    k_e = jnp.repeat(k, rep, axis=2)
    v_e = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k_e.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v_e.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    qf = _pad_to(qf, LANE, 2)
    kf = _pad_to(kf, LANE, 2)
    vf = _pad_to(vf, LANE, 2)
    out = _fa.flash_attention_pallas(qf, kf, vf, causal=causal,
                                     window=window, bq=bq, bk=bk,
                                     scale=d ** -0.5,
                                     interpret=_interpret())
    out = out[:, :, :d].reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return out


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------
@jax.jit
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """q: [B,H,D]; k_pages/v_pages: [n_slots, bt, KV, D];
    block_tables: [B, MB]; seq_lens: [B].
    Returns (out [B,H,D], touched [B,MB] bool)."""
    b, h, d = q.shape
    kv = k_pages.shape[2]
    rep = h // kv
    qg = q.reshape(b, kv, rep, d)
    qg = _pad_to(qg, LANE, 3)
    kp = _pad_to(k_pages, LANE, 3)
    vp = _pad_to(v_pages, LANE, 3)
    out, touched = _pa.paged_attention_pallas(
        qg, kp, vp, block_tables, seq_lens, scale=d ** -0.5,
        interpret=_interpret())
    out = out[..., :d].reshape(b, h, d)
    return out, touched.astype(bool)


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("chunk", "ct"))
def mamba_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               chunk: int = 64, ct: int = 8):
    """a,b: [B,S,C,N]; h0: [B,C,N] -> (h_all fp32, h_last fp32)."""
    n = a.shape[-1]
    ap = _pad_to(a.astype(jnp.float32), LANE, 3)
    bp = _pad_to(b.astype(jnp.float32), LANE, 3)
    h0p = _pad_to(h0.astype(jnp.float32), LANE, 2)
    # pad a with 1s would corrupt? a-pad lanes multiply zeros of h0/b: all
    # padded lanes stay 0 regardless of a's pad value (h0,b pads are 0).
    h_all, h_last = _ms.mamba_scan_pallas(ap, bp, h0p, chunk=chunk, ct=ct,
                                          interpret=_interpret())
    return h_all[..., :n], h_last[..., :n]
