"""Pallas TPU kernels for the framework's compute hot-spots:

  migrate          Object Collector data mover (scalar-prefetched
                   gather/scatter through VMEM)
  access_scan      collector table sweep (CIW update + MXU histogram)
  paged_attention  decode through the object table, fused access bits
  flash_attention  training/prefill attention (online softmax, SWA)
  mamba_scan       selective-SSM recurrence (sequential-grid carry)

`ops` holds the jit'd public wrappers; `ref` the pure-jnp oracles.
Kernels run in interpret mode on CPU and compile natively on TPU.
"""
from repro.kernels import ops, ref  # noqa: F401
