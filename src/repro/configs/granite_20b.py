"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code. [arXiv:2405.04324; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152, head_dim=128,
        rope_theta=10000.0, mlp_gated=False,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16, mlp_gated=False,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("granite-20b", full, reduced)
