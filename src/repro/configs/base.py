"""Configuration system for the HADES-JAX framework.

A single frozen dataclass (`ModelConfig`) describes every assigned
architecture family: dense decoder-only, GQA/MQA, sliding-window attention,
MoE, encoder-decoder, VLM backbone, SSM (mamba1/mamba2) and hybrids.

Configs are registered by id in `REGISTRY`; `get_config(arch_id)` returns the
full published config, `get_config(arch_id, reduced=True)` returns a
CPU-smoke-test-sized config of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds — a model is a sequence of blocks; dense transformers repeat one
# kind, hybrids (zamba2) interleave kinds.
# ---------------------------------------------------------------------------
ATTN = "attn"          # full (or windowed) self-attention + MLP/MoE
MAMBA1 = "mamba1"      # mamba-1 selective SSM block
MAMBA2 = "mamba2"      # mamba-2 (SSD) block
SHARED_ATTN = "shared_attn"  # zamba2's shared attention block (tied params)


@dataclasses.dataclass(frozen=True)
class HadesConfig:
    """Frontend (paper technique) configuration."""
    enabled: bool = True
    # object granularity: KV blocks of this many tokens
    kv_block_tokens: int = 16
    # superblock = contiguous run of this many slots (the "huge page" unit)
    superblock_slots: int = 64
    # collector cadence (collector runs every N serve/train steps)
    collect_every: int = 8
    # CIW demotion threshold C_t (initial; adapted by MIAD)
    ciw_threshold: int = 3
    ciw_min: int = 1
    ciw_max: int = 16
    # MIAD: promotion-rate target and control gains
    promotion_target: float = 0.01
    miad_mult: float = 2.0        # multiplicative increase of C_t
    miad_add: int = 1             # additive decrease of C_t
    # fraction of pool slots reserved for the NEW heap
    new_frac: float = 0.125
    # tiering backend: any name registered in `repro.core.backend`
    # ("reactive" / "proactive" / "cap" / "null" / "mglru" / "promote",
    # see backend.names()); runtimes build it via backend.make(name),
    # which rejects typos at construction time
    backend: str = "reactive"
    # hot-tier capacity as a fraction of total pool (cap backend analog)
    hot_capacity_frac: float = 0.5
    # embedding tiering: number of hot rows kept in HBM (0 = disabled)
    embed_hot_rows: int = 0
    # int8-quantize cold-tier KV (beyond-paper optimization, off by default
    # so the paper-faithful baseline stays bit-exact)
    cold_quantize: bool = False
    # --- §Perf hillclimb variants (beyond-paper, off by default) ---
    # decode-time MoE: gather only the routed experts' weights (the HADES
    # hot-expert principle applied to the weight stream)
    expert_gather_decode: bool = False
    # KV cache store precision for decode (16 = bf16 baseline; 8 = int8
    # + per-block scales, halving the dominant decode HBM term)
    kv_quant_bits: int = 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # moe | dense | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention flavour ---
    sliding_window: int = 0          # 0 = full attention; >0 = SWA window
    rope_theta: float = 10000.0
    rope_style: str = "rope"         # rope | mrope | rope2d | none
    attn_logit_softcap: float = 0.0
    # --- FFN ---
    mlp_gated: bool = True           # SwiGLU (3 mats) vs GELU MLP (2 mats)
    # --- MoE ---
    num_experts: int = 0             # 0 = dense FFN
    experts_per_token: int = 0
    moe_d_ff: int = 0                # expert hidden dim (olmoe: 1024)
    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0         # frame/patch count from stub frontend
    # --- SSM / hybrid ---
    block_pattern: Tuple[str, ...] = ()   # per-layer block kinds; () = all ATTN
    ssm_state_dim: int = 0
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    shared_attn_every: int = 0       # zamba2: shared attn block period
    # --- modality frontend stub ---
    frontend: str = "none"           # none | audio | vision
    # --- norm / misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- paper technique ---
    hades: HadesConfig = dataclasses.field(default_factory=HadesConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        return (ATTN,) * self.num_layers

    @property
    def is_attention_free(self) -> bool:
        return all(b in (MAMBA1, MAMBA2) for b in self.blocks)

    @property
    def subquadratic(self) -> bool:
        """True if decode memory for attention state is o(seq) or windowed."""
        if self.is_attention_free:
            return True
        if self.family == "hybrid":
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q = self.num_heads * h
        n_kv = self.num_kv_heads * h
        total = 0
        for kind in self.blocks:
            if kind in (ATTN, SHARED_ATTN):
                n_ff_mats = 3 if self.mlp_gated else 2
                attn = d * n_q + 2 * d * n_kv + n_q * d
                if self.num_experts:
                    ff = n_ff_mats * d * (self.moe_d_ff or self.d_ff) * self.num_experts
                    ff += d * self.num_experts  # router
                else:
                    ff = n_ff_mats * d * self.d_ff
                total += attn + ff + 2 * d
            else:  # mamba block
                d_in = d * self.ssm_expand
                n = self.ssm_state_dim
                # in_proj (x,z), conv, dt/B/C proj, out_proj
                total += d * 2 * d_in + d_in * self.ssm_conv_dim
                total += d_in * (n * 2 + 1) + d_in * d + 2 * d
        if self.is_encoder_decoder:
            # encoder self-attn+ff and decoder cross-attn
            enc = self.num_encoder_layers * (
                2 * (d * n_q + 2 * d * n_kv + n_q * d) // 2 + 3 * d * self.d_ff + 2 * d
            )
            cross = self.num_layers * (d * n_q + 2 * d * n_kv + n_q * d)
            total += enc + cross
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        eff = self.moe_d_ff or self.d_ff
        n_ff_mats = 3 if self.mlp_gated else 2
        inactive = n_ff_mats * d * eff * (self.num_experts - self.experts_per_token)
        n_moe_layers = sum(1 for k in self.blocks if k in (ATTN, SHARED_ATTN))
        return self.param_count() - inactive * n_moe_layers


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
REDUCED_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    REGISTRY[arch_id] = full
    REDUCED_REGISTRY[arch_id] = reduced


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    # importing the configs package populates the registry
    import repro.configs  # noqa: F401
    reg = REDUCED_REGISTRY if reduced else REGISTRY
    if arch_id not in reg:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return reg[arch_id]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(REGISTRY))
