"""Architecture configs. Importing this package populates the registry."""
from repro.configs.base import (REGISTRY, ModelConfig, HadesConfig,
                                get_config, list_archs)  # noqa: F401
from repro.configs import shapes  # noqa: F401

# registration side effects
from repro.configs import (  # noqa: F401
    mixtral_8x7b,
    olmoe_1b_7b,
    seamless_m4t_large_v2,
    qwen2_vl_72b,
    glm4_9b,
    granite_20b,
    granite_34b,
    chatglm3_6b,
    zamba2_2_7b,
    falcon_mamba_7b,
)
