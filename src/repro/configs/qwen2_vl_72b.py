"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution. Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings. [arXiv:2409.12191; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        rope_style="mrope", rope_theta=1e6,
        frontend="vision",
        hades=HadesConfig(embed_hot_rows=8192),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        rope_style="mrope",
        frontend="vision",
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("qwen2-vl-72b", full, reduced)
