"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, GQA. [hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=151552, head_dim=128,
        rope_theta=10000.0,
        hades=HadesConfig(embed_hot_rows=8192),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("glm4-9b", full, reduced)
