"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1024 vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304, head_dim=128,
        rope_theta=10000.0,
        num_experts=64, experts_per_token=8, moe_d_ff=1024,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256, head_dim=16,
        num_experts=8, experts_per_token=2, moe_d_ff=32,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("olmoe-1b-7b", full, reduced)
