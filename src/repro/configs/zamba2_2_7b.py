"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

Block pattern: every 6th block is the SHARED attention block (tied params
across occurrences, as in the published architecture); the rest are Mamba2.
"""
from repro.configs.base import (HadesConfig, MAMBA2, ModelConfig,
                                SHARED_ATTN, register)


def _pattern(n_layers: int, every: int):
    return tuple(SHARED_ATTN if (i + 1) % every == 0 else MAMBA2
                 for i in range(n_layers))


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=10240, vocab_size=32000, head_dim=80,
        block_pattern=_pattern(54, 6), shared_attn_every=6,
        ssm_state_dim=64, ssm_conv_dim=4, ssm_expand=2,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        block_pattern=_pattern(4, 2), shared_attn_every=2,
        ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("zamba2-2.7b", full, reduced)
