"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 architecture. [arXiv:2410.05355; unverified]

Attention-free: HADES KV-cache tiering is inapplicable (DESIGN.md §3.5) —
the recurrent state is a single always-hot object. HADES still manages the
embedding table for this arch.
"""
from repro.configs.base import HadesConfig, MAMBA1, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=65024, head_dim=64,
        rope_style="none",
        block_pattern=(MAMBA1,) * 64,
        ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=256, head_dim=16,
        rope_style="none",
        block_pattern=(MAMBA1,) * 2,
        ssm_state_dim=8, ssm_conv_dim=4, ssm_expand=2,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("falcon-mamba-7b", full, reduced)
