"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d-RoPE, GQA. [arXiv:2406.12793; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024, head_dim=128,
        rope_style="rope2d", rope_theta=10000.0,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        rope_style="rope2d",
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("chatglm3-6b", full, reduced)
