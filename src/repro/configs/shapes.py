"""Assigned input shapes and (arch x shape) applicability.

Shapes (LM transformer family — seq_len x global_batch):
  train_4k      seq_len=4096    global_batch=256   -> train_step
  prefill_32k   seq_len=32768   global_batch=32    -> serve prefill
  decode_32k    seq_len=32768   global_batch=128   -> serve_step (1 new token,
                                                      KV cache of seq_len)
  long_500k     seq_len=524288  global_batch=1     -> serve_step; requires
                                                      sub-quadratic attention
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_ORDER: Tuple[str, ...] = (
    "train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is runnable; reason if not.

    Per assignment: long_500k needs sub-quadratic attention — skipped for
    pure full-attention archs (noted in DESIGN.md); runs for SSM/hybrid/SWA.
    Encoder-only archs would skip decode shapes; none are assigned.
    """
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 524k decode is N/A (DESIGN.md §3.5)"
    return True, ""


def reduced_shape(shape_name: str) -> ShapeSpec:
    """Tiny analog of each shape for CPU smoke tests."""
    spec = SHAPES[shape_name]
    return ShapeSpec(spec.name + "_smoke", seq_len=min(spec.seq_len, 64),
                     global_batch=min(spec.global_batch, 2), mode=spec.mode)
