"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        sliding_window=4096, rope_theta=1e6,
        num_experts=8, experts_per_token=2, moe_d_ff=14336,
        hades=HadesConfig(embed_hot_rows=4096),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        sliding_window=32, rope_theta=1e6,
        num_experts=4, experts_per_token=2, moe_d_ff=128,
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=32),
    )


register("mixtral-8x7b", full, reduced)
