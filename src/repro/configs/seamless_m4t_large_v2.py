"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal. Audio frontend is a STUB:
input_specs() provides precomputed frame embeddings. [arXiv:2308.11596; hf]
"""
from repro.configs.base import HadesConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        rope_style="none",  # learned/sinusoidal positions in m4t; none for backbone
        is_encoder_decoder=True, num_encoder_layers=24,
        encoder_seq_len=1024, frontend="audio",
        hades=HadesConfig(embed_hot_rows=8192),  # 256k vocab: biggest embed win
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        rope_style="none",
        is_encoder_decoder=True, num_encoder_layers=2,
        encoder_seq_len=16, frontend="audio",
        hades=HadesConfig(kv_block_tokens=4, superblock_slots=4,
                          embed_hot_rows=64),
    )


register("seamless-m4t-large-v2", full, reduced)
