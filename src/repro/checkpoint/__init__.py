from repro.checkpoint.ckpt import (Checkpointer, latest_step,  # noqa: F401
                                   restore, save)
