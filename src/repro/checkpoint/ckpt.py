"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step:
    <dir>/step_<N>.tmp/            (written)
        shard_<k>.npz              one file per leaf-chunk group
        manifest.json              leaf treedef + shapes/dtypes + chunks
    <dir>/step_<N>/                (atomic rename on completion)

Fault-tolerance properties:
  * atomic commit — a crash mid-write leaves only a .tmp dir, never a
    half-valid checkpoint; `latest_step` ignores .tmp;
  * async — `Checkpointer.save_async` snapshots device arrays to host
    (blocking only for the copy) and writes on a background thread, so
    the train loop overlaps I/O with compute;
  * elastic restore — arrays are saved UNSHARDED (gathered per leaf) and
    re-sharded on load against whatever mesh the restoring job has, so
    a 512-chip checkpoint restores on 256 chips (elastic rescale);
  * bounded retention — keep_last prunes old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(path: str, step: int, tree: Any, *, extra: Optional[Dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    names, leaves, _ = _flatten_with_paths(tree)
    tmp = os.path.join(path, f"step_{step}.tmp")
    final = os.path.join(path, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    host = [np.asarray(leaf) for leaf in leaves]
    dtypes = [str(a.dtype) for a in host]
    # npz cannot round-trip ml_dtypes (bfloat16 etc.) — store a uint16/
    # uint8 view and record the logical dtype in the manifest
    arrays = {}
    for i, a in enumerate(host):
        if a.dtype.kind not in "biufc":  # not a native numpy numeric
            a = a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,))
        arrays[f"leaf_{i}"] = a
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "shapes": [list(np.shape(a)) for a in host],
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit
    _prune(path, keep_last)
    return final


def _prune(path: str, keep_last: int) -> None:
    steps = sorted(latest_steps(path))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, f"step_{s}"), ignore_errors=True)


def latest_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(path, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return out


def latest_step(path: str) -> Optional[int]:
    steps = latest_steps(path)
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`; apply `shardings` (same
    pytree structure or a single sharding) if given — this is the elastic
    re-shard point: the stored arrays are unsharded."""
    final = os.path.join(path, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "shard_0.npz"))
    names, _, treedef = _flatten_with_paths(like)
    assert names == manifest["names"], "checkpoint/model structure mismatch"
    import ml_dtypes  # ships with jax

    def _dtype(name):
        try:
            return np.dtype(name)
        except TypeError:
            return np.dtype(getattr(ml_dtypes, name))

    leaves = []
    for i in range(len(names)):
        a = data[f"leaf_{i}"]
        want_dtype = manifest["dtypes"][i]
        if a.dtype == np.uint8 and want_dtype not in ("uint8",):
            a = a.reshape(-1).view(_dtype(want_dtype)).reshape(
                manifest["shapes"][i])
        leaves.append(a)
    if shardings is not None:
        shard_leaves = (jax.tree.leaves(shardings)
                        if not hasattr(shardings, "device_set")
                        else [shardings] * len(leaves))
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jax.device_put(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_extra(path: str, step: int) -> Dict:
    with open(os.path.join(path, f"step_{step}", "manifest.json")) as f:
        return json.load(f)["extra"]


class Checkpointer:
    """Async wrapper: snapshot to host, write on a daemon thread."""

    def __init__(self, path: str, keep_last: int = 3):
        self.path = path
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot (blocking)

        def _write():
            save(self.path, step, host_tree, extra=extra,
                 keep_last=self.keep_last)
        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
