"""Data substrate: YCSB workloads + the ten data-structure access
topologies (paper Table 1), the CrestKV driver, and the LM token pipeline."""
from repro.data.ycsb import WORKLOADS, ZipfianKeys  # noqa: F401
from repro.data.structures import STRUCTURES, make_structure  # noqa: F401
