"""The ten concurrent data structures (paper Table 1) as access-topology
generators.

The paper evaluates HADES across ten ASCYLIB structures to show that
object-level tracking is robust to pointer-graph shape and concurrency
control. What tiering actually *sees* from a structure is the object
access stream each operation induces — which index/metadata objects are
touched on the way to the key/value, and which synchronization words are
shared. We reproduce exactly that: each structure precomputes its search
paths over the loaded key set and emits, per operation, the flat array of
object ids touched. Concurrency control appears as extra touched objects
(global locks, per-node lock/version words, epoch counters) — a coarse
lock is one scorching-hot object; per-node words scale with the path.

Object-id address map (driver-level; n = number of keys):
    [0,       n)        key objects     (30 B)
    [n,      2n)        per-key node objects (chain/tower/leaf-entry)
    [2n,     2n+M)      structure metadata (buckets, internal nodes, locks)
    value objects are allocated dynamically by the driver (1024 B),
    starting at `value_base` (updates allocate fresh value objects).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

KEY_BYTES = 30
VALUE_BYTES = 1024
NODE_BYTES = 32
LOCK_BYTES = 16
BTREE_NODE_BYTES = 1024
MASSTREE_NODE_BYTES = 256
ART_NODE_BYTES = 128


class Structure:
    """Base: subclasses fill `meta_sizes` and implement `paths`."""
    name = "base"
    node_bytes = NODE_BYTES

    def __init__(self, n_keys: int, seed: int = 0):
        self.n = n_keys
        self.rng = np.random.default_rng(seed)
        self.key_base = 0
        self.node_base = n_keys
        self.meta_base = 2 * n_keys
        # sorted order: key k has rank `rank_of[k]`; key_at_rank inverts
        self.key_at_rank = self.rng.permutation(n_keys)
        self.rank_of = np.empty(n_keys, np.int64)
        self.rank_of[self.key_at_rank] = np.arange(n_keys)
        self._build()

    # -- to be provided by subclasses ----------------------------------------
    def _build(self):
        raise NotImplementedError

    def paths(self, op_keys: np.ndarray, is_update: np.ndarray) -> np.ndarray:
        """[n_ops, depth] object ids touched per op (-1 = no touch)."""
        raise NotImplementedError

    # -- common ---------------------------------------------------------------
    def meta_objects(self) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, sizes) of structure metadata objects to allocate at load."""
        sizes = np.asarray(self.meta_sizes, np.int64)
        ids = self.meta_base + np.arange(len(sizes), dtype=np.int64)
        return ids, sizes

    def node_objects(self) -> Tuple[np.ndarray, np.ndarray]:
        ids = self.node_base + np.arange(self.n, dtype=np.int64)
        return ids, np.full(self.n, self.node_bytes, np.int64)

    def touched(self, op_keys: np.ndarray, is_update: np.ndarray,
                value_obj: np.ndarray) -> np.ndarray:
        """Flat object-id stream for a batch of ops: index path + key +
        current value object."""
        p = self.paths(op_keys, is_update)
        cols = [p, (self.key_base + op_keys)[:, None], value_obj[:, None]]
        flat = np.concatenate(cols, axis=1).ravel()
        return flat[flat >= 0]


# ---------------------------------------------------------------------------
# Hash tables
# ---------------------------------------------------------------------------
class _HashBase(Structure):
    """Chained hash table, load factor 1. Chain walk touches the node
    objects of chain predecessors (insertion order)."""
    max_chain = 4
    extra_locks = 0  # number of lock objects per op (subclass)

    def _build(self):
        n = self.n
        self.n_buckets = n
        h = self.rng.permutation(n)          # bucket of key
        self.bucket_of = h % self.n_buckets
        # chain rank: order within bucket
        order = np.lexsort((np.arange(n), self.bucket_of))
        ranks = np.empty(n, np.int64)
        grp_start = np.concatenate([[0], np.nonzero(
            np.diff(self.bucket_of[order]))[0] + 1])
        starts = np.zeros(n, np.int64)
        starts[grp_start] = 1
        ranks[order] = np.arange(n) - np.maximum.accumulate(
            np.where(starts == 1, np.arange(n), -1))
        self.chain_rank = ranks
        self.sorted_by_bucket = order        # keys grouped by bucket
        self.pos_in_sorted = np.empty(n, np.int64)
        self.pos_in_sorted[order] = np.arange(n)
        # metadata: one bucket-head object per bucket (+ locks, subclass)
        self.meta_sizes = [16] * self.n_buckets + \
            [LOCK_BYTES] * self._n_lock_objects()
        self.lock_base = self.meta_base + self.n_buckets

    def _n_lock_objects(self) -> int:
        return 0

    def _lock_touch(self, op_keys: np.ndarray) -> List[np.ndarray]:
        return []

    def paths(self, op_keys: np.ndarray, is_update: np.ndarray) -> np.ndarray:
        bucket_obj = self.meta_base + self.bucket_of[op_keys]
        # chain predecessors: up to max_chain-1 node objects before ours
        r = self.chain_rank[op_keys]
        pos = self.pos_in_sorted[op_keys]
        depth = np.minimum(r, self.max_chain - 1)
        preds = []
        for i in range(self.max_chain - 1):
            take = i < depth
            idx = np.clip(pos - depth + i, 0, self.n - 1)
            pk = self.sorted_by_bucket[idx]
            preds.append(np.where(take, self.node_base + pk, -1))
        own = self.node_base + op_keys
        cols = [bucket_obj[:, None]] + [p[:, None] for p in preds] + \
            [own[:, None]] + [t[:, None] for t in self._lock_touch(op_keys)]
        return np.concatenate(cols, axis=1)


class HashHarris(_HashBase):
    """Harris lock-free list — no lock objects (CAS on next pointers)."""
    name = "hash-harris"


class HashPugh(_HashBase):
    """Pugh: fine-grained r/w lock per bucket."""
    name = "hash-pugh"

    def _n_lock_objects(self):
        return self.n_buckets

    def _lock_touch(self, op_keys):
        return [self.lock_base + self.bucket_of[op_keys]]


class HashCHM(_HashBase):
    """Java CHM: segmented bucket locks (16 segments)."""
    name = "hash-chm"
    N_SEG = 16

    def _n_lock_objects(self):
        return self.N_SEG

    def _lock_touch(self, op_keys):
        return [self.lock_base + self.bucket_of[op_keys] % self.N_SEG]


# ---------------------------------------------------------------------------
# Skip lists — search path touches tower nodes at descending levels
# ---------------------------------------------------------------------------
class _SkipBase(Structure):
    def _build(self):
        self.levels = max(2, int(math.log2(max(self.n, 2))))
        self.meta_sizes = self._meta()
        self.lock_base = self.meta_base

    def _meta(self) -> List[int]:
        return []

    def _locks(self, op_keys, is_update) -> List[np.ndarray]:
        return []

    def paths(self, op_keys, is_update):
        r = self.rank_of[op_keys]
        cols = []
        # descend: predecessor at level l is the rank with low l bits cleared
        for l in range(self.levels - 1, -1, -1):
            pred = (r >> l) << l
            cols.append((self.node_base +
                         self.key_at_rank[pred])[:, None])
        cols += [t[:, None] for t in self._locks(op_keys, is_update)]
        return np.concatenate(cols, axis=1)


class SkipCoarse(_SkipBase):
    """Global-lock skiplist (LevelDB memtable style) — one molten object."""
    name = "skip-coarse"

    def _meta(self):
        return [LOCK_BYTES]

    def _locks(self, op_keys, is_update):
        return [np.full(len(op_keys), self.lock_base, np.int64)]


class SkipFraser(_SkipBase):
    """Fraser lock-free skiplist (Redis sorted-set analog)."""
    name = "skip-fraser"


class SkipHerlihy(_SkipBase):
    """Herlihy optimistic: per-node lock words on pred/curr."""
    name = "skip-herlihy"

    def _meta(self):
        return [LOCK_BYTES] * self.n

    def _locks(self, op_keys, is_update):
        r = self.rank_of[op_keys]
        pred = self.key_at_rank[np.maximum(r - 1, 0)]
        return [self.lock_base + pred, self.lock_base + op_keys]


# ---------------------------------------------------------------------------
# B+Trees — root + internals are shared-hot; leaves follow key skew
# ---------------------------------------------------------------------------
class _BTreeBase(Structure):
    fanout = 64
    node_size = BTREE_NODE_BYTES

    def _build(self):
        f = self.fanout
        self.depth = max(1, math.ceil(math.log(max(self.n, 2), f)))
        # level l (0 = leaves): n_l = ceil(n / f^(l+1)) internal nodes
        self.level_sizes = [max(1, -(-self.n // f ** (l + 1)))
                            for l in range(self.depth)]
        self.level_base = np.cumsum([0] + self.level_sizes[:-1])
        self.meta_sizes = [self.node_size] * sum(self.level_sizes) + \
            self._extra_meta()
        self.extra_base = self.meta_base + sum(self.level_sizes)

    def _extra_meta(self) -> List[int]:
        return []

    def _extra(self, op_keys, is_update) -> List[np.ndarray]:
        return []

    def paths(self, op_keys, is_update):
        f = self.fanout
        r = self.rank_of[op_keys]
        cols = []
        for l in range(self.depth - 1, -1, -1):  # root .. leaf-parent
            node = r // f ** (l + 1)
            cols.append((self.meta_base + self.level_base[l] + node)[:, None])
        cols.append((self.node_base + op_keys)[:, None])  # leaf entry
        cols += [t[:, None] for t in self._extra(op_keys, is_update)]
        return np.concatenate(cols, axis=1)


class BTreeCoarse(_BTreeBase):
    """Global-lock B+Tree (SAP HANA style)."""
    name = "btree-coarse"

    def _extra_meta(self):
        return [LOCK_BYTES]

    def _extra(self, op_keys, is_update):
        return [np.full(len(op_keys), self.extra_base, np.int64)]


class BTreeOCC(_BTreeBase):
    """OCC B+Tree with epoch-based reclamation (VoltDB index style):
    every op touches the global epoch object; version words live inside
    the node objects already on the path."""
    name = "btree-occ"

    def _extra_meta(self):
        return [LOCK_BYTES]

    def _extra(self, op_keys, is_update):
        return [np.full(len(op_keys), self.extra_base, np.int64)]


class MassTree(_BTreeBase):
    """Masstree: trie of B+trees — modelled as a deeper, narrower tree
    (fanout 16) + RCU epoch object."""
    name = "masstree"
    fanout = 16
    node_size = MASSTREE_NODE_BYTES

    def _extra_meta(self):
        return [LOCK_BYTES]

    def _extra(self, op_keys, is_update):
        return [np.full(len(op_keys), self.extra_base, np.int64)]


# ---------------------------------------------------------------------------
# Adaptive Radix Tree — radix-256 path over the hashed key
# ---------------------------------------------------------------------------
class ART(Structure):
    """ART with fine-grained r/w locks: 4-level radix path on the hashed
    key; lock word per touched node (modelled for inner levels)."""
    name = "art"
    LEVELS = 4

    def _build(self):
        self.hash = self.rng.permutation(self.n).astype(np.int64)
        # level l: nodes keyed by the top (l+1) bytes of a 4-byte hash;
        # level sizes saturate at n
        self.level_sizes = [min(self.n, 256 ** (l + 1))
                            for l in range(self.LEVELS - 1)]
        self.level_base = np.cumsum([0] + self.level_sizes[:-1])
        n_nodes = sum(self.level_sizes)
        self.meta_sizes = [ART_NODE_BYTES] * n_nodes + \
            [LOCK_BYTES] * n_nodes
        self.lock_base = self.meta_base + n_nodes

    def paths(self, op_keys, is_update):
        h = self.hash[op_keys]
        cols = []
        for l in range(self.LEVELS - 1):
            node = (h >> (8 * (self.LEVELS - 1 - l))) % self.level_sizes[l]
            nid = self.level_base[l] + node
            cols.append((self.meta_base + nid)[:, None])
            cols.append((self.lock_base + nid)[:, None])
        cols.append((self.node_base + op_keys)[:, None])
        return np.concatenate(cols, axis=1)


STRUCTURES: Dict[str, type] = {
    s.name: s for s in (
        HashHarris, HashPugh, HashCHM,
        SkipCoarse, SkipFraser, SkipHerlihy,
        BTreeCoarse, BTreeOCC, MassTree, ART)
}


def make_structure(name: str, n_keys: int, seed: int = 0) -> Structure:
    return STRUCTURES[name](n_keys, seed)
