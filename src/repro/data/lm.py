"""Synthetic LM token pipeline — deterministic, shardable, replayable.

Tokens are drawn zipfian over the vocabulary (real corpora are zipfian —
this is what makes embedding-row tiering representative) from a counter-
based PRNG keyed on (seed, step, shard): any step of any shard can be
regenerated independently, which is what makes the fault-tolerant trainer
replay-exact after restore (runtime/trainer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_theta: float = 1.1
    seed: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        # zipfian inverse-CDF over the vocab (heavy head, long tail)
        w = 1.0 / np.power(
            np.arange(1, cfg.vocab_size + 1, dtype=np.float64),
            cfg.zipf_theta)
        cdf = np.cumsum(w)
        self._cdf = jnp.asarray(cdf / cdf[-1], jnp.float32)
        # scatter hot ids across the vocab (realistic id assignment)
        self._scramble = jnp.asarray(
            np.random.default_rng(cfg.seed).permutation(cfg.vocab_size))

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        """Deterministic batch for (step, shard) — replay-exact."""
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
            self.shard)
        u = jax.random.uniform(key, (self.local_batch, cfg.seq_len + 1))
        ranks = jnp.searchsorted(self._cdf, u)
        toks = self._scramble[jnp.clip(ranks, 0, cfg.vocab_size - 1)]
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
