"""YCSB workload generation (paper §5 setup).

Zipfian key streams with *scattered* hot keys: YCSB's stock generator
concentrates hot keys at low ids; real deployments (and the paper's setup)
see hot keys scattered throughout the key space, which is what makes
hotness fragmentation bite. We therefore apply a fixed random permutation
("scramble") to the zipf ranks, exactly like YCSB's ScrambledZipfian.

Workload mixes (YCSB core):
    A: 50% reads / 50% updates
    B: 95% reads /  5% updates
    C: 100% reads
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np

ZIPF_THETA = 0.99  # YCSB default skew


@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    name: str
    read_frac: float
    update_frac: float


WORKLOADS: Dict[str, WorkloadMix] = {
    "A": WorkloadMix("A", 0.5, 0.5),
    "B": WorkloadMix("B", 0.95, 0.05),
    "C": WorkloadMix("C", 1.0, 0.0),
}


class ZipfianKeys:
    """Scrambled-zipfian key sampler over [0, n_keys).

    `active_frac` reproduces the paper's working-set construction (fig 7:
    "12GB footprint while actively accessing only ~4GB"): requests are
    zipfian over the first `active_frac * n` ranks, and the scramble
    scatters those active keys throughout the whole key space — so the
    active set is a scattered 1/3 (say) of the footprint, exactly the
    hotness-fragmentation regime the paper evaluates.
    """

    def __init__(self, n_keys: int, theta: float = ZIPF_THETA,
                 seed: int = 0, active_frac: float = 1.0):
        self.n = n_keys
        self.theta = theta
        self.rng = np.random.default_rng(seed)
        n_active = max(1, int(n_keys * active_frac))
        # inverse-CDF tables: P(rank <= r) = zeta(r)/zeta(n_active)
        weights = 1.0 / np.power(np.arange(1, n_active + 1, dtype=np.float64),
                                 theta)
        self.cdf = np.cumsum(weights)
        self.cdf /= self.cdf[-1]
        # scatter hot (and active) ranks across the whole key space
        self.scramble = self.rng.permutation(n_keys)

    def sample(self, k: int) -> np.ndarray:
        u = self.rng.random(k)
        ranks = np.searchsorted(self.cdf, u)
        return self.scramble[ranks]

    def hot_set(self, frac: float) -> np.ndarray:
        """The keys covering the top `frac` of access probability."""
        n_hot = max(1, int(np.searchsorted(self.cdf, frac)))
        return self.scramble[:n_hot]


def ops_stream(mix: WorkloadMix, keys: ZipfianKeys, n_ops: int,
               batch: int = 4096, seed: int = 1
               ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (op_is_update [b], keys [b]) batches, deterministic per seed."""
    rng = np.random.default_rng(seed)
    done = 0
    while done < n_ops:
        b = min(batch, n_ops - done)
        ks = keys.sample(b)
        upd = rng.random(b) < mix.update_frac
        yield upd, ks
        done += b
