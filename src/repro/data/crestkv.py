"""CrestKV — the lightweight concurrent KV store of the paper's evaluation.

CrestKV drives any of the ten Table-1 structures over a SimHeap address
space, reproducing the paper's experimental conditions:

  * load phase interleaves key/node/value allocations per insertion —
    exactly the allocation-order placement that creates hotness
    fragmentation once the access skew arrives;
  * run phase samples scrambled-zipfian YCSB ops; updates allocate fresh
    value objects and free old ones (the NEW-heap churn in fig 6a);
  * every `window_ops`, the heap arms tracking, runs the Object
    Collector, and lets the configured backend reclaim.

Metrics mirror the paper's: per-window page utilization, RSS, promotion
rate, fault count, and an op-level time model for throughput/latency
(base op cost + access-bit tracking + scope-guard + fault penalties).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.simheap import NEW, SimConfig, SimHeap
from repro.data.structures import (KEY_BYTES, VALUE_BYTES, Structure,
                                   make_structure)
from repro.data.ycsb import WORKLOADS, WorkloadMix, ZipfianKeys, ops_stream


@dataclasses.dataclass
class RunStats:
    windows: List[Dict]
    ops: int
    total_ns: float
    base_ns: float
    faults: int

    @property
    def throughput_mops(self) -> float:
        return self.ops / max(self.total_ns, 1) * 1e3

    @property
    def overhead_frac(self) -> float:
        """Fractional slowdown vs the untracked baseline op cost."""
        return (self.total_ns - self.base_ns) / max(self.base_ns, 1)

    @property
    def mean_latency_ns(self) -> float:
        return self.total_ns / max(self.ops, 1)


class CrestKV:
    def __init__(self, structure: str, n_keys: int, sim_cfg: SimConfig,
                 seed: int = 0, value_bytes: int = VALUE_BYTES):
        self.struct: Structure = make_structure(structure, n_keys, seed)
        self.n_keys = n_keys
        self.value_bytes = value_bytes
        self.heap = SimHeap(sim_cfg, seed)
        # value-object id management (updates churn ids)
        meta_ids, meta_sizes = self.struct.meta_objects()
        self.value_base = int(meta_ids[-1]) + 1 if len(meta_ids) else \
            self.struct.meta_base
        self.value_obj = self.value_base + np.arange(n_keys, dtype=np.int64)
        self._free_ids: List[int] = []
        self._next_id = self.value_base + n_keys
        self._load(meta_ids, meta_sizes)

    # -- load phase -----------------------------------------------------------
    def _load(self, meta_ids: np.ndarray, meta_sizes: np.ndarray) -> None:
        """Allocate metadata, then interleave (key, node, value) per
        insertion — the fragmentation-inducing baseline layout."""
        if len(meta_ids):
            self.heap.alloc(meta_ids, meta_sizes, heap=NEW)
        node_ids, node_sizes = self.struct.node_objects()
        key_ids = np.arange(self.n_keys, dtype=np.int64)
        ids = np.empty(3 * self.n_keys, np.int64)
        sizes = np.empty(3 * self.n_keys, np.int64)
        ids[0::3], ids[1::3], ids[2::3] = key_ids, node_ids, self.value_obj
        sizes[0::3] = KEY_BYTES
        sizes[1::3] = node_sizes
        sizes[2::3] = self.value_bytes
        self.heap.alloc(ids, sizes, heap=NEW)
        # Load complete: clear load-time access bits WITHOUT classifying —
        # the run starts with the paper's "initial object classification
        # phase" (fig 6a), not with a pre-classified heap.
        h = self.heap
        h.access[:] = False
        h.atc[:] = 0
        h.referenced[:] = False
        h.win_accesses = h.win_promos = 0
        h.win_first_obs = h.win_faults = h.win_track_ops = 0

    # -- run phase --------------------------------------------------------------
    def _alloc_values(self, n: int) -> np.ndarray:
        take = min(len(self._free_ids), n)
        out = np.empty(n, np.int64)
        if take:
            out[:take] = self._free_ids[-take:]
            del self._free_ids[-take:]
        fresh = n - take
        if fresh:
            out[take:] = self._next_id + np.arange(fresh)
            self._next_id += fresh
        return out

    def run(self, workload: str, n_ops: int, *, window_ops: int = 50_000,
            batch: int = 4096, seed: int = 1, active_frac: float = 1 / 3,
            on_window=None) -> RunStats:
        """`active_frac` defaults to the paper's fig-7 working-set ratio
        (~4GB active of a 12GB footprint), scattered across the keyspace."""
        mix = WORKLOADS[workload]
        keys = ZipfianKeys(self.n_keys, seed=seed, active_frac=active_frac)
        heap = self.heap
        since_collect = 0
        ops_done = 0
        for upd, ks in ops_stream(mix, keys, n_ops, batch=batch, seed=seed):
            touched = self.struct.touched(ks, upd, self.value_obj[ks])
            heap.access_objects(touched)
            if upd.any():
                uk = ks[upd]
                uk, uniq_idx = np.unique(uk, return_index=True)
                old = self.value_obj[uk]
                heap.free(old)
                self._free_ids.extend(old.tolist())
                new_ids = self._alloc_values(len(uk))
                heap.alloc(new_ids, np.full(len(uk), self.value_bytes,
                                            np.int64))
                self.value_obj[uk] = new_ids
            ops_done += len(ks)
            since_collect += len(ks)
            if since_collect >= window_ops:
                heap.arm()          # epoch protocol: arm, then collect
                report = heap.collect()
                heap.backend_step()
                # report RSS as the backend left it
                report["rss_bytes"] = heap.rss_bytes()
                since_collect = 0
                if on_window is not None:
                    on_window(report)
        base_ns = ops_done * heap.cfg.base_op_ns
        return RunStats(windows=list(heap.window_log), ops=ops_done,
                        total_ns=base_ns + heap.total_ns, base_ns=base_ns,
                        faults=heap.total_faults)


def default_sim_config(n_keys: int, *, backend: str = "reactive",
                       hbm_target_bytes: int = 0, enabled: bool = True,
                       value_bytes: int = VALUE_BYTES) -> SimConfig:
    """Size a SimHeap for a CrestKV instance: per-heap range fits all
    objects with 2x churn slack."""
    approx_bytes = n_keys * (KEY_BYTES + 64 + value_bytes) * 2 + (1 << 22)
    max_objects = 8 * n_keys + (1 << 16)
    return SimConfig(max_objects=max_objects, heap_bytes=approx_bytes,
                     backend=backend, hbm_target_bytes=hbm_target_bytes,
                     enabled=enabled)
