"""In-scan token sampling for the fused serving windows.

The sampler runs INSIDE the decode scan (runtime/server.py): the PRNG
key rides the window carry and is split exactly once per model step, and
the per-lane temperature / top-k parameters are carried DATA (rewritten
by lane events at window boundaries), so one compiled window program
serves any mix of greedy and sampled lanes without recompiling or
syncing to the host mid-window.

Key-carry rules (docs/serving.md):

* Whether sampling runs at all is STATIC per generate/serve call (the
  server's `do_sample` program variant): all-greedy calls compile the
  bare argmax transition and never pay the sampler's [B, V] sort +
  Gumbel draw — nor touch the key.
* While sampling is enabled: ONE split per model step — teacher-forced
  steps and greedy (temperature <= 0) lanes consume randomness too. A
  lane's sample stream is therefore a function of (seed, global step
  index) only, never of the other lanes' modes or of how the steps were
  chunked into windows: the per-step and windowed paths stay
  bit-identical with sampling on.
* `temperature <= 0` selects greedy argmax for that lane — bit-identical
  to the pre-sampler serving path (the noise is computed and discarded,
  which is what keeps the scan branch-free).
* `top_k <= 0` disables the top-k filter for that lane.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
           top_k: jax.Array) -> jax.Array:
    """One sampling step across the batch.

    logits: [B, V]; key: one PRNG key for the step; temperature [B]
    float32 (<= 0 -> greedy argmax); top_k [B] int32 (<= 0 -> full
    vocab). Returns tok [B] int32.

    Per-lane top-k with a traced k: the per-lane threshold is the k-th
    largest logit (one sort over [B, V] — the vocab axis is tiny next to
    the model step's matmuls), logits below it drop to -inf, and the
    draw is a Gumbel-max over the kept set — equivalent to renormalized
    top-k categorical sampling, with no host round trip and no
    data-dependent shapes inside the scan."""
    b, v = logits.shape
    lg = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, -1).astype(jnp.int32)
    srt = jnp.sort(lg, axis=-1)[:, ::-1]                 # descending
    kth = jnp.take_along_axis(
        srt, jnp.clip(top_k[:, None] - 1, 0, v - 1), axis=-1)
    keep = (top_k[:, None] <= 0) | (lg >= kth)
    noise = jax.random.gumbel(key, (b, v), jnp.float32)
    scored = jnp.where(keep,
                       lg / jnp.maximum(temperature, 1e-6)[:, None] + noise,
                       -jnp.inf)
    sampled_tok = jnp.argmax(scored, -1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled_tok, greedy_tok)
