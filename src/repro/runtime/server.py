"""Batched decode server with the HADES-managed paged KV cache.

Serving loop per step: embed -> per-layer (qkv, paged-attend through the
object table, ffn) -> logits -> sample; every `collect_every` steps the
Object Collector tidies the KV pool (arm the window one step earlier —
the epoch protocol) and the backend reclaims cold superblocks.

Continuous batching-lite: finished sequences free their KV blocks and
their lanes are refilled from the pending queue.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as be
from repro.core import collector as col
from repro.core import pool as pl
from repro.models import kvcache as kvc
from repro.models import layers as L


@dataclasses.dataclass
class ServerConfig:
    batch: int = 8
    max_len: int = 256
    block_tokens: int = 16
    collect_every: int = 8
    backend: str = "proactive"
    eos_token: int = 2


class Server:
    """Decode-only server for attention-family models (dense/GQA/MoE)."""

    def __init__(self, model, cfg: ServerConfig):
        assert not model.cfg.block_pattern, \
            "paged serving targets attention archs (SSM decode is O(1))"
        self.model = model
        self.cfg = cfg
        mc = model.cfg
        self.kv_cfg = kvc.KVCacheConfig(
            num_layers=mc.num_layers, batch=cfg.batch,
            max_blocks=-(-cfg.max_len // cfg.block_tokens),
            block_tokens=cfg.block_tokens, num_kv_heads=mc.num_kv_heads,
            head_dim=mc.resolved_head_dim, dtype=mc.dtype)
        self.col_cfg = col.CollectorConfig()
        self.be_cfg = be.BackendConfig(kind=cfg.backend)
        self.state = kvc.init(self.kv_cfg)
        self._steps = 0
        self.reports: List[Dict] = []
        # collector + backend as ONE compiled transition (engine path);
        # RSS/host gauges come back inside the report — no extra syncs
        self._collect_fused = jax.jit(functools.partial(
            kvc.collect_and_backend, self.kv_cfg, self.col_cfg,
            self.be_cfg))

    # -- one decode step across the batch -------------------------------------
    def decode_step(self, params, tokens: jax.Array
                    ) -> Tuple[jax.Array, None]:
        """tokens: [B] -> logits [B, V]. Appends to the paged cache and
        attends through the object table with the Pallas kernel."""
        mc: ModelConfig = self.model.cfg
        cfg = self.kv_cfg
        x = L.embed(params["embed"], tokens)[:, None, :]   # [B,1,D]
        pos = self.state["pos"]
        b = tokens.shape[0]
        hd = mc.resolved_head_dim

        # compute all layers' k/v for this token, append once, then attend
        ks, vs, hs = [], [], []
        h = x
        layers = params["layers"]
        positions = pos[:, None]
        from repro.models import transformer as T
        for li in range(mc.num_layers):
            lp = jax.tree.map(lambda a: a[li], layers)
            hn = L.rms_norm(h, lp["ln1"], mc.norm_eps)
            q, k, v = T._qkv(lp, hn, mc, positions)
            ks.append(k[:, 0])
            vs.append(v[:, 0])
            hs.append((lp, q))
            # placeholder: h advanced after appends (two-phase)
        kv_k = jnp.stack(ks)                    # [L, B, KV, D]
        kv_v = jnp.stack(vs)
        self.state = kvc.append(cfg, self.state, kv_k, kv_v)

        h = x
        for li in range(mc.num_layers):
            lp, q = hs[li]
            hn = L.rms_norm(h, lp["ln1"], mc.norm_eps)
            q, _, _ = T._qkv(lp, hn, mc, pos[:, None])
            out, self.state = kvc.attend(cfg, self.state, li, q[:, 0])
            h = h + jnp.einsum("be,ed->bd", out.reshape(b, -1),
                               lp["wo"])[:, None]
            h2 = L.rms_norm(h, lp["ln2"], mc.norm_eps)
            if mc.num_experts:
                from repro.models import moe as moe_lib
                f, _, _ = moe_lib.moe_block(lp["moe"], h2, mc)
            else:
                f = L.mlp(lp["ffn"], h2, mc.mlp_gated)
            h = h + f

        h = L.rms_norm(h, params["final_ln"], mc.norm_eps)
        out_t = params["embed"].T if mc.tie_embeddings else params["out"]
        logits = L.logits_head(out_t, h)[:, 0]

        # HADES cadence: collect -> backend. The loop is synchronous (the
        # step completed before the collector runs) so the window is NOT
        # armed — ATC arming is for runtimes that overlap dispatch with
        # collection (see HadesOptions.overlap_collect).
        self._steps += 1
        every = self.cfg.collect_every
        if self._steps % every == 0:
            # one dispatch: collect + MIAD + candidate marking + backend,
            # with the RSS/host gauges computed on-device (engine path)
            self.state, report = self._collect_fused(self.state)
            self.reports.append({k: float(v) for k, v in report.items()})
        return logits, None

    # -- generate --------------------------------------------------------------
    def generate(self, params, prompts: jax.Array, max_new: int,
                 *, greedy: bool = True, key=None) -> jax.Array:
        """prompts: [B, P] (decoded token-by-token — prefill through the
        same paged path exercises HADES on the prefix blocks)."""
        b, p = prompts.shape
        outs = []
        tok = None
        for t in range(p):
            logits, _ = self.decode_step(params, prompts[:, t])
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
        for _ in range(max_new - 1):
            logits, _ = self.decode_step(params, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
        return jnp.stack(outs, axis=1)

    # -- metrics -----------------------------------------------------------------
    def kv_rss_bytes(self) -> float:
        return float(pl.rss_bytes(self.kv_cfg.pool_config(),
                                  self.state["pool"]))
