"""Batched decode server with the HADES-managed paged KV cache.

The serving hot path runs as SCANNED DECODE WINDOWS: `decode_window`
executes W decode steps — embed, per-layer (qkv -> paged append -> attend
through the object table -> ffn), logits, sample, and the window-closing
collect+MIAD+backend — as ONE jitted `lax.scan`, built on the same
`engine.window_program` machinery (and therefore the same op-clock /
collect-cadence contract) as `Engine.run_window`. `decode_step` is the
per-step reference path: the identical transition, one dispatch per
token, bit-identical to the windowed path (tests/test_server_window.py).

Per layer the residual stream `h` advances BEFORE the next layer's k/v is
derived (each layer's k/v is a function of the previous layers' output —
the old two-phase loop computed every layer's k/v from the embedding and
wrote corrupted bytes into the paged pool).

`overlap_collect=True` is the double-buffered serving loop the ATC/arm
epoch protocol exists for: windows arm one step before closing (objects
dereferenced by an in-flight step carry ATC > 0 and are never migrated),
and `generate` defers each window's report sync until the NEXT window's
dispatch has been issued — collection resolves while decode runs.

CONTINUOUS BATCHING (`Server.serve`, docs/serving.md): lanes carry a
lifecycle — admit -> decode -> finish on EOS/max-tokens -> free -> refill
from the request queue. Lane events resolve at window boundaries and ride
the window dispatch itself (`engine.window_program`'s `pre_fn` plumbing):
finishing a lane frees ALL of its KV objects through the pool op stream
before the window's first step, so churn stays at exactly ONE dispatch
per window while freed cold blocks become the fragmentation the
collector tidies for the backend to reclaim. Sampling (temperature /
top-k, per lane) runs INSIDE the scan under a carried PRNG key
(runtime/sampling.py).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import backend as be
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import pool as pl
from repro.models import kvcache as kvc
from repro.models import layers as L
from repro.models import transformer as T
from repro.runtime import sampling


@dataclasses.dataclass
class ServerConfig:
    batch: int = 8
    max_len: int = 256
    block_tokens: int = 16
    collect_every: int = 8
    # tiering backend: any registered name (backend.names()) + its
    # constructor params, built via backend.make at Server construction
    # (typos fail here, not inside a jitted trace)
    backend: str = "proactive"
    backend_params: Optional[Dict] = None
    eos_token: int = 2
    # decode-window length W used by `generate`/`serve` (0 ->
    # collect_every): W steps run as ONE dispatch, window protocol
    # included
    window: int = 0
    # double-buffered serving: windows arm the ATC epoch one step before
    # closing, and `generate`/`serve` sync window N's report only after
    # window N+1's dispatch is in flight
    overlap_collect: bool = False
    # route the collector through the Pallas kernels (interpret on CPU)
    use_pallas: bool = False
    # in-scan sampling defaults for `generate(greedy=False)`:
    # temperature <= 0 is greedy argmax, top_k <= 0 keeps the full vocab
    # (per-request overrides live on `Request`)
    temperature: float = 1.0
    top_k: int = 0


@dataclasses.dataclass
class Request:
    """One generation request for `Server.serve` (continuous batching).
    temperature <= 0 decodes greedily; top_k <= 0 disables the top-k
    filter. Sampled requests (temperature > 0) need `serve(key=...)`."""
    prompt: Sequence[int]
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0


@dataclasses.dataclass
class Completion:
    """`Server.serve`'s per-request result. `tokens` are the generated
    tokens (EOS included when it fired); `finish_reason` is "eos" or
    "length" (max_new or lane capacity); `windows` is the [admitted,
    finished] window-index span the request occupied a lane for."""
    rid: int
    tokens: List[int]
    finish_reason: str
    windows: Tuple[int, int]


@dataclasses.dataclass
class _Lane:
    """Host-side lane bookkeeping between window boundaries."""
    rid: int
    req: Request
    admitted_at: int
    steps: int = 0                   # model steps consumed since admit
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    reason: str = ""


class Server:
    """Decode-only server for attention-family models (dense/GQA/MoE)."""

    def __init__(self, model, cfg: ServerConfig):
        assert not model.cfg.block_pattern, \
            "paged serving targets attention archs (SSM decode is O(1))"
        self.model = model
        self.cfg = cfg
        mc = model.cfg
        self.kv_cfg = kvc.KVCacheConfig(
            num_layers=mc.num_layers, batch=cfg.batch,
            max_blocks=-(-cfg.max_len // cfg.block_tokens),
            block_tokens=cfg.block_tokens, num_kv_heads=mc.num_kv_heads,
            head_dim=mc.resolved_head_dim, dtype=mc.dtype)
        self.col_cfg = col.CollectorConfig(use_pallas=cfg.use_pallas)
        self.backend = be.make(cfg.backend, **(cfg.backend_params or {}))
        self.reports: List[Dict] = []
        self.serve_log: List[Dict] = []     # per-window churn/RSS gauges
        self._build_programs()
        self.reset()

    # -- compiled programs -----------------------------------------------------
    def _model_step(self, params, state, tok):
        """The fused decode transition: tok [B] -> (state', logits [B,V]).
        Layers run under lax.scan; each layer derives qkv from the CURRENT
        residual stream (exactly once), appends its k/v to the paged pool
        and attends through the object table. Inactive lanes append
        nothing and attend over zero keys (kvcache's lane mask)."""
        mc: ModelConfig = self.model.cfg
        cfg = self.kv_cfg
        x = L.embed(params["embed"], tok)[:, None, :]   # [B,1,D]
        positions = state["pos"][:, None]               # [B,1]

        def layer_body(carry, xs):
            h, st = carry
            li, lp = xs

            def attend(q, k, v):
                st2 = kvc.append_layer(cfg, st, li, k[:, 0], v[:, 0])
                # pos still points AT the appended token (advance_pos
                # runs after the layer scan) -> the token attends to
                # itself via pos + 1
                out, st3 = kvc.attend(cfg, st2, li, q[:, 0],
                                      seq_lens=st2["pos"] + 1)
                return out[:, None], st3                # [B,1,H,Dh]

            h, st, _ = T.decode_layer_step(lp, h, mc, positions, attend)
            return (h, st), None

        (h, state), _ = jax.lax.scan(
            layer_body, (x, state),
            (jnp.arange(mc.num_layers), params["layers"]))
        state = kvc.advance_pos(state)
        h = L.rms_norm(h, params["final_ln"], mc.norm_eps)
        out_t = params["embed"].T if mc.tie_embeddings else params["out"]
        logits = L.logits_head(out_t, h)[:, 0]
        return state, logits

    def _build_programs(self):
        every = int(self.cfg.collect_every)
        overlap = bool(self.cfg.overlap_collect)
        cab = functools.partial(kvc.collect_and_backend, self.kv_cfg,
                                self.col_cfg, self.backend)

        def win_step(params, do_sample, carry, forced):
            """One window step: forced token (>= 0) or self-feed the
            previously sampled one (inactive lanes decode a pinned pad
            token; the lane mask drops their pool traffic). With
            `do_sample` (static — a property of the generate/serve
            call) the in-scan sampler picks the next token under the
            carried PRNG key — split once per step, forced steps
            included — with the carried per-lane temperature/top-k
            (temperature <= 0 lanes take argmax); without it the step
            is the bare argmax transition, so the greedy hot path never
            pays the sampler's [B, V] sort + Gumbel draw."""
            tok = jnp.where(forced >= 0, forced, carry["tok"])
            tok = jnp.where(carry["kv"]["active"], tok, 0)
            kvstate, logits = self._model_step(params, carry["kv"], tok)
            if do_sample:
                key, sub = jax.random.split(carry["key"])
                nxt = sampling.sample(logits, sub, carry["temp"],
                                      carry["topk"])
                carry = dict(carry, kv=kvstate, tok=nxt, key=key)
            else:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                carry = dict(carry, kv=kvstate, tok=nxt)
            return carry, {"logits": logits, "tok": nxt}

        def win_collect(carry):
            kvstate, report = cab(carry["kv"])
            return dict(carry, kv=kvstate), report

        def win_arm(carry):
            return dict(carry, kv=kvc.arm(carry["kv"]))

        def win_events(carry, ev):
            """Window-entry lane events, fused into the window dispatch:
            finished lanes free ALL their KV through the pool op stream,
            refilled lanes reset their clock and load their sampling
            params. ev: {"free","admit" [B] bool, "temp" [B] f32,
            "topk" [B] i32}."""
            kv = kvc.free_lanes(self.kv_cfg, carry["kv"], ev["free"])
            kv = kvc.admit_lanes(kv, ev["admit"])
            return dict(carry, kv=kv,
                        temp=jnp.where(ev["admit"], ev["temp"],
                                       carry["temp"]),
                        topk=jnp.where(ev["admit"], ev["topk"],
                                       carry["topk"]))

        def _programs(params, do_sample, pre_fn=None):
            return eng.window_program(
                functools.partial(win_step, params, do_sample),
                win_collect, win_arm,
                every=every, overlap=overlap, pre_fn=pre_fn)

        def aligned(params, carry, toks, do_sample):
            return _programs(params, do_sample)[1](carry, toks)

        def generic(params, carry, toks, step0, do_sample):
            return _programs(params, do_sample)[0](carry, toks, step0)

        def serve_aligned(params, carry, toks, events, do_sample):
            """The continuous-batching window: lane events applied at
            the window entry, then W steps + collect — one dispatch."""
            return _programs(params, do_sample,
                             pre_fn=win_events)[1](carry, toks, events)

        def step_apply(params, carry, tok, do_arm, do_collect,
                       do_sample):
            """decode_step's program: the identical transition, collect
            and arm fused in statically (the host knows the clock)."""
            carry, out = win_step(params, do_sample, carry, tok)
            if do_arm:
                carry = win_arm(carry)
            if do_collect:
                carry, report = win_collect(carry)
            else:
                report = eng.zero_report()
            return carry, out, report

        # the decode carry (KV pool + last tokens + sampling key/params)
        # is DONATED: each window updates the paged pool in place instead
        # of double-buffering it per dispatch. params (argnum 0) are NOT
        # donated — they are reused every call. The server never touches
        # a carry after passing it in (all carried leaves are reassigned
        # from the returned carry; tests/test_donation.py). `do_sample`
        # is static: the greedy variant compiles without the sampler.
        self._win_aligned = jax.jit(aligned, donate_argnums=(1,),
                                    static_argnames=("do_sample",))
        self._win_generic = jax.jit(generic, donate_argnums=(1,),
                                    static_argnames=("do_sample",))
        self._win_serve = jax.jit(serve_aligned, donate_argnums=(1,),
                                  static_argnames=("do_sample",))
        self._step_apply = jax.jit(
            step_apply,
            static_argnames=("do_arm", "do_collect", "do_sample"),
            donate_argnums=(1,))

    # -- the decode carry (donated per dispatch, mirrors reassigned) ----------
    def _carry(self) -> Dict:
        return {"kv": self.state, "tok": self._last_tok, "key": self._key,
                "temp": self._temp, "topk": self._topk}

    def _uncarry(self, carry: Dict) -> None:
        self.state, self._last_tok = carry["kv"], carry["tok"]
        self._key = carry["key"]
        self._temp, self._topk = carry["temp"], carry["topk"]

    # -- one decode step across the batch -------------------------------------
    def decode_step(self, params, tokens: jax.Array
                    ) -> Tuple[jax.Array, None]:
        """tokens: [B] -> (logits [B, V], None). ONE dispatch: the model
        step plus — statically, from the host-side window clock — the ATC
        arm and the fused collect+MIAD+backend. The per-step reference
        for `decode_window` (bit-identical transitions)."""
        nxt = self._steps + 1
        every = self.cfg.collect_every
        do_arm = bool(self.cfg.overlap_collect) and \
            nxt % every == every - 1
        do_collect = nxt % every == 0
        carry, out, report = self._step_apply(
            params, self._carry(), jnp.asarray(tokens, jnp.int32),
            do_arm=do_arm, do_collect=do_collect,
            do_sample=self._sample_in_scan)
        self._uncarry(carry)
        self._steps += 1
        self.dispatches += 1
        if do_collect:
            self.reports.append({k: float(v) for k, v in report.items()})
        return out["logits"], None

    # -- scanned decode windows ------------------------------------------------
    def decode_window(self, params, tokens: jax.Array,
                      w: Optional[int] = None):
        """Run a whole decode window as ONE dispatch.

        tokens: [B, T] int32 — entries >= 0 are teacher-forced, entries
        < 0 self-feed the previously sampled token; or [B] (a seed token
        per sequence) with `w` given, running `w` steps (seed then
        self-feed). Every step embeds, runs all layers (paged append +
        attend), computes logits and samples; window-closing steps run
        the fused collect+MIAD+backend in the same program (and, with
        overlap_collect, arm the ATC epoch one step earlier). Uses the
        cond-free window-aligned program when T and the op clock align
        with collect_every, the generic cond-gated one otherwise.

        Returns (logits [B, T, V], sampled [B, T], per-step report
        pytree — feed to engine.window_reports to extract the collects)."""
        toks = jnp.asarray(tokens, jnp.int32)
        if toks.ndim == 1:
            toks = jnp.concatenate(
                [toks[:, None],
                 jnp.full((toks.shape[0], (w or 1) - 1), -1, jnp.int32)],
                axis=1)
        toks = toks.T                                   # scan axis first
        t = int(toks.shape[0])
        every = self.cfg.collect_every
        carry = self._carry()
        if t > 0 and t % every == 0 and self._steps % every == 0:
            carry, outs, reports = self._win_aligned(
                params, carry, toks, do_sample=self._sample_in_scan)
        else:
            carry, outs, reports = self._win_generic(
                params, carry, toks, self._steps,
                do_sample=self._sample_in_scan)
        self._uncarry(carry)
        self._steps += t
        self.dispatches += 1
        return (outs["logits"].transpose(1, 0, 2), outs["tok"].T, reports)

    # -- generate --------------------------------------------------------------
    def generate(self, params, prompts: jax.Array, max_new: int,
                 *, greedy: bool = True, key=None) -> jax.Array:
        """prompts: [B, P], teacher-forced through the same scanned decode
        path (prefill exercises HADES on the prefix blocks), then
        `max_new` tokens — window-by-window (W = cfg.window or
        collect_every), O(tokens / W) dispatches.

        `greedy=True` decodes argmax (bit-identical to the pre-sampler
        path; `key` is optional and only seeds the carried PRNG).
        `greedy=False` samples IN-SCAN with cfg.temperature/cfg.top_k on
        every lane and REQUIRES `key` — sampling without randomness used
        to fall back to greedy silently; now it refuses. (A
        cfg.temperature <= 0 still means argmax — that is lane
        configuration, not a fallback.)

        With overlap_collect the loop is double-buffered: window N's
        report sync (the only host<->device round trip) happens only
        after window N+1's dispatch is in flight, so collection resolves
        while the next window decodes."""
        if not greedy and key is None:
            raise ValueError(
                "generate(greedy=False) samples inside the decode scan "
                "and needs an explicit PRNG `key`")
        b, p = prompts.shape
        if key is not None:
            self._key = jnp.asarray(key)
        self._sample_in_scan = not greedy
        if greedy:
            self._temp = jnp.zeros((b,), jnp.float32)
            self._topk = jnp.zeros((b,), jnp.int32)
        else:
            self._temp = jnp.full((b,), self.cfg.temperature, jnp.float32)
            self._topk = jnp.full((b,), self.cfg.top_k, jnp.int32)
        if max_new <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        total = p + max_new - 1
        forced = jnp.concatenate(
            [jnp.asarray(prompts, jnp.int32),
             jnp.full((b, max_new - 1), -1, jnp.int32)], axis=1)
        w = self.cfg.window or self.cfg.collect_every
        sampled = []
        pending = None
        for lo in range(0, total, w):
            _, toks, rep = self.decode_window(params, forced[:, lo:lo + w])
            sampled.append(toks)
            if self.cfg.overlap_collect:
                if pending is not None:
                    self.reports.extend(eng.window_reports(pending))
                pending = rep
            else:
                self.reports.extend(eng.window_reports(rep))
        if pending is not None:
            self.reports.extend(eng.window_reports(pending))
        out = jnp.concatenate(sampled, axis=1)          # [B, total]
        return out[:, p - 1:]

    # -- continuous batching ---------------------------------------------------
    def serve(self, params, requests: Sequence[Request], *, key=None,
              max_windows: Optional[int] = None) -> List[Completion]:
        """Continuous-batching queue driver (docs/serving.md).

        Rides the fused serving window at exactly ONE dispatch per
        window: each iteration resolves lane events on the host (finish
        -> free, queue -> admit), builds the window's forced-token
        matrix (prompt tokens teacher-forced per lane, -1 self-feeds,
        inactive lanes pinned to 0) and dispatches the event+window
        program — the finished lanes' KV objects are freed through the
        pool op stream INSIDE that dispatch, before the first step. The
        sampled tokens sync back at the window boundary (the host must
        inspect them to schedule lanes — the sync a continuous batcher
        cannot avoid, paid once per W tokens); with overlap_collect the
        collect REPORT sync is still deferred one window.

        A lane finishes on EOS, on its request's max_new, or at the
        lane capacity (cfg.max_len). Prompts must fit a lane
        (0 < len < max_len — longer ones would silently truncate).
        Finished lanes keep decoding until their window ends (overshoot
        tokens are dropped on the host and freed with the lane); the
        final lanes drain through one last all-inactive window so every
        request's KV leaves the pool through the same op stream. Starts
        from a fresh pool (`reset(active=False)`) and ends back in the
        fixed-batch contract (drained pool, all lanes active at pos 0);
        per-window RSS/live-bytes/churn gauges land in `self.serve_log`.

        Returns one `Completion` per request, in submission order."""
        w = self.cfg.window or self.cfg.collect_every
        every = self.cfg.collect_every
        if w % every != 0:
            raise ValueError(
                f"serve needs window ({w}) aligned to collect_every "
                f"({every}) — lane events ride the aligned window shape")
        b = self.cfg.batch
        do_sample = any(r.temperature > 0 for r in requests)
        if key is None and do_sample:
            raise ValueError(
                "serve() got sampled requests (temperature > 0) but no "
                "PRNG `key`")
        for rid, r in enumerate(requests):
            if not 0 < len(r.prompt) < self.cfg.max_len:
                raise ValueError(
                    f"request {rid}: prompt length {len(r.prompt)} must "
                    f"be in [1, max_len={self.cfg.max_len}) — longer "
                    "prompts would silently truncate (KV appends past "
                    "lane capacity are dropped)")
            if r.max_new < 1:
                raise ValueError(
                    f"request {rid}: max_new={r.max_new} — a lane "
                    "always emits at least one token")
        self.reset(active=False)
        self._sample_in_scan = do_sample
        if key is not None:
            self._key = jnp.asarray(key)
        queue = collections.deque(enumerate(requests))
        lanes: List[Optional[_Lane]] = [None] * b
        results: List[Optional[Completion]] = [None] * len(requests)
        if max_windows is None:
            # generous safety valve: sequential worst case + drain
            max_windows = 2 + sum(
                -(-(len(r.prompt) + r.max_new) // w) + 1 for r in requests)
        window_idx = 0
        pending = None
        while True:
            # -- resolve lane events (host side, window boundary) --------
            free = np.zeros((b,), bool)
            admit = np.zeros((b,), bool)
            temp = np.zeros((b,), np.float32)
            topk = np.zeros((b,), np.int32)
            for i in range(b):
                ln = lanes[i]
                if ln is not None and ln.done:
                    free[i] = True
                    results[ln.rid] = Completion(
                        ln.rid, ln.out, ln.reason,
                        (ln.admitted_at, window_idx))
                    lanes[i] = None
                if lanes[i] is None and queue:
                    rid, req = queue.popleft()
                    lanes[i] = _Lane(rid=rid, req=req,
                                     admitted_at=window_idx)
                    admit[i] = True
                    temp[i] = req.temperature
                    topk[i] = req.top_k
            if not any(lanes) and not free.any():
                break                     # queue drained, pool empty
            if window_idx >= max_windows:
                raise RuntimeError(
                    f"serve exceeded max_windows={max_windows} "
                    "(lane scheduling stuck?)")

            # -- the window's forced tokens ------------------------------
            toks = np.zeros((b, w), np.int32)
            for i, ln in enumerate(lanes):
                if ln is None:
                    continue
                row = np.full((w,), -1, np.int32)
                prompt = ln.req.prompt
                n_force = min(max(len(prompt) - ln.steps, 0), w)
                row[:n_force] = prompt[ln.steps:ln.steps + n_force]
                toks[i] = row

            # -- ONE dispatch: events + W steps + collect ----------------
            events = {
                "free": jnp.zeros((w, b), jnp.bool_).at[0].set(free),
                "admit": jnp.zeros((w, b), jnp.bool_).at[0].set(admit),
                "temp": jnp.zeros((w, b), jnp.float32).at[0].set(temp),
                "topk": jnp.zeros((w, b), jnp.int32).at[0].set(topk),
            }
            carry, outs, rep = self._win_serve(
                params, self._carry(), jnp.asarray(toks.T), events,
                do_sample=do_sample)
            self._uncarry(carry)
            self._steps += w
            self.dispatches += 1
            window_idx += 1
            if self.cfg.overlap_collect:
                if pending is not None:
                    self.reports.extend(eng.window_reports(pending))
                pending = rep
            else:
                self.reports.extend(eng.window_reports(rep))

            # -- window-boundary sync: schedule lanes off the samples ----
            sampled = np.asarray(outs["tok"]).T          # [B, w]
            for i, ln in enumerate(lanes):
                if ln is None:
                    continue
                p = len(ln.req.prompt)
                for t in range(w):
                    if ln.done:
                        break
                    s = ln.steps + t
                    if s < p - 1:
                        continue                          # prompt phase
                    ln.out.append(int(sampled[i, t]))
                    if ln.out[-1] == self.cfg.eos_token:
                        ln.done, ln.reason = True, "eos"
                    elif len(ln.out) >= ln.req.max_new:
                        ln.done, ln.reason = True, "length"
                    elif s + 1 >= self.cfg.max_len:
                        ln.done, ln.reason = True, "length"
                ln.steps += w
            self.serve_log.append({
                "window": window_idx,
                "active": sum(ln is not None for ln in lanes),
                "admitted": int(admit.sum()), "freed": int(free.sum()),
                "queued": len(queue),
                "rss_bytes": self.kv_rss_bytes(),
                "live_bytes": self.kv_live_bytes(),
            })
        if pending is not None:
            self.reports.extend(eng.window_reports(pending))
        assert all(r is not None for r in results)
        # the pool is drained; hand the server back in the fixed-batch
        # contract (all lanes live at pos 0) so a later generate /
        # decode_step does not silently decode on masked lanes
        self.state = dict(self.state,
                          active=jnp.ones((b,), jnp.bool_))
        self._sample_in_scan = False
        return results

    def reset(self, active: bool = True) -> None:
        """Fresh serving state (empty pool, zeroed clock/reports/sampling
        carry) without dropping the compiled programs — shapes are
        geometry-only, so benchmarks and multi-request drivers restart
        instantly. `active=False` starts every lane empty (the
        continuous-batching driver admits lanes through window
        events)."""
        self.state = kvc.init(self.kv_cfg, backend=self.backend,
                              active=active)
        self._steps = 0
        self._last_tok = jnp.zeros((self.cfg.batch,), jnp.int32)
        self._key = jax.random.PRNGKey(0)
        self._temp = jnp.zeros((self.cfg.batch,), jnp.float32)  # greedy
        self._topk = jnp.zeros((self.cfg.batch,), jnp.int32)
        self._sample_in_scan = False        # static program variant
        self.reports = []
        self.serve_log = []
        self.dispatches = 0                 # host-side dispatch count

    # -- metrics -----------------------------------------------------------------
    def kv_rss_bytes(self) -> float:
        return float(pl.rss_bytes(self.kv_cfg.pool_config(),
                                  self.state["pool"]))

    def kv_live_bytes(self) -> float:
        """Bytes of LIVE KV objects (allocated blocks x slot bytes) —
        the floor `kv_rss_bytes` reaches at zero fragmentation; the gap
        between the two is what the collector + backend reclaim."""
        n = int(jnp.sum(self.state["block_tables"] >= 0))
        return float(n * self.kv_cfg.pool_config().slot_bytes)
