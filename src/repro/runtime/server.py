"""Batched decode server with the HADES-managed paged KV cache.

The serving hot path runs as SCANNED DECODE WINDOWS: `decode_window`
executes W decode steps — embed, per-layer (qkv -> paged append -> attend
through the object table -> ffn), logits, sample, and the window-closing
collect+MIAD+backend — as ONE jitted `lax.scan`, built on the same
`engine.window_program` machinery (and therefore the same op-clock /
collect-cadence contract) as `Engine.run_window`. `decode_step` is the
per-step reference path: the identical transition, one dispatch per
token, bit-identical to the windowed path (tests/test_server_window.py).

Per layer the residual stream `h` advances BEFORE the next layer's k/v is
derived (each layer's k/v is a function of the previous layers' output —
the old two-phase loop computed every layer's k/v from the embedding and
wrote corrupted bytes into the paged pool).

`overlap_collect=True` is the double-buffered serving loop the ATC/arm
epoch protocol exists for: windows arm one step before closing (objects
dereferenced by an in-flight step carry ATC > 0 and are never migrated),
and `generate` defers each window's report sync until the NEXT window's
dispatch has been issued — collection resolves while decode runs.

Continuous batching-lite: finished sequences free their KV blocks and
their lanes are refilled from the pending queue.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import backend as be
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import pool as pl
from repro.models import kvcache as kvc
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass
class ServerConfig:
    batch: int = 8
    max_len: int = 256
    block_tokens: int = 16
    collect_every: int = 8
    # tiering backend: any registered name (backend.names()) + its
    # constructor params, built via backend.make at Server construction
    # (typos fail here, not inside a jitted trace)
    backend: str = "proactive"
    backend_params: Optional[Dict] = None
    eos_token: int = 2
    # decode-window length W used by `generate` (0 -> collect_every):
    # W steps run as ONE dispatch, window protocol included
    window: int = 0
    # double-buffered serving: windows arm the ATC epoch one step before
    # closing, and `generate` syncs window N's report only after window
    # N+1's dispatch is in flight
    overlap_collect: bool = False
    # route the collector through the Pallas kernels (interpret on CPU)
    use_pallas: bool = False


class Server:
    """Decode-only server for attention-family models (dense/GQA/MoE)."""

    def __init__(self, model, cfg: ServerConfig):
        assert not model.cfg.block_pattern, \
            "paged serving targets attention archs (SSM decode is O(1))"
        self.model = model
        self.cfg = cfg
        mc = model.cfg
        self.kv_cfg = kvc.KVCacheConfig(
            num_layers=mc.num_layers, batch=cfg.batch,
            max_blocks=-(-cfg.max_len // cfg.block_tokens),
            block_tokens=cfg.block_tokens, num_kv_heads=mc.num_kv_heads,
            head_dim=mc.resolved_head_dim, dtype=mc.dtype)
        self.col_cfg = col.CollectorConfig(use_pallas=cfg.use_pallas)
        self.backend = be.make(cfg.backend, **(cfg.backend_params or {}))
        self.state = kvc.init(self.kv_cfg, backend=self.backend)
        self._steps = 0                     # host mirror of the op clock
        self._last_tok = jnp.zeros((cfg.batch,), jnp.int32)
        self.reports: List[Dict] = []
        self.dispatches = 0                 # host-side dispatch count
        self._build_programs()

    # -- compiled programs -----------------------------------------------------
    def _model_step(self, params, state, tok):
        """The fused decode transition: tok [B] -> (state', logits [B,V]).
        Layers run under lax.scan; each layer derives qkv from the CURRENT
        residual stream (exactly once), appends its k/v to the paged pool
        and attends through the object table."""
        mc: ModelConfig = self.model.cfg
        cfg = self.kv_cfg
        x = L.embed(params["embed"], tok)[:, None, :]   # [B,1,D]
        positions = state["pos"][:, None]               # [B,1]

        def layer_body(carry, xs):
            h, st = carry
            li, lp = xs

            def attend(q, k, v):
                st2 = kvc.append_layer(cfg, st, li, k[:, 0], v[:, 0])
                # pos still points AT the appended token (advance_pos
                # runs after the layer scan) -> the token attends to
                # itself via pos + 1
                out, st3 = kvc.attend(cfg, st2, li, q[:, 0],
                                      seq_lens=st2["pos"] + 1)
                return out[:, None], st3                # [B,1,H,Dh]

            h, st, _ = T.decode_layer_step(lp, h, mc, positions, attend)
            return (h, st), None

        (h, state), _ = jax.lax.scan(
            layer_body, (x, state),
            (jnp.arange(mc.num_layers), params["layers"]))
        state = kvc.advance_pos(state)
        h = L.rms_norm(h, params["final_ln"], mc.norm_eps)
        out_t = params["embed"].T if mc.tie_embeddings else params["out"]
        logits = L.logits_head(out_t, h)[:, 0]
        return state, logits

    def _build_programs(self):
        every = int(self.cfg.collect_every)
        overlap = bool(self.cfg.overlap_collect)
        cab = functools.partial(kvc.collect_and_backend, self.kv_cfg,
                                self.col_cfg, self.backend)

        def win_step(params, carry, forced):
            """One window step: forced token (>= 0) or self-feed the
            previously sampled one; greedy sample for the next step."""
            tok = jnp.where(forced >= 0, forced, carry["tok"])
            kvstate, logits = self._model_step(params, carry["kv"], tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (dict(kv=kvstate, tok=nxt),
                    {"logits": logits, "tok": nxt})

        def win_collect(carry):
            kvstate, report = cab(carry["kv"])
            return dict(carry, kv=kvstate), report

        def win_arm(carry):
            return dict(carry, kv=kvc.arm(carry["kv"]))

        def _programs(params):
            return eng.window_program(
                functools.partial(win_step, params), win_collect, win_arm,
                every=every, overlap=overlap)

        def aligned(params, carry, toks):
            return _programs(params)[1](carry, toks)

        def generic(params, carry, toks, step0):
            return _programs(params)[0](carry, toks, step0)

        def step_apply(params, carry, tok, do_arm, do_collect):
            """decode_step's program: the identical transition, collect
            and arm fused in statically (the host knows the clock)."""
            carry, out = win_step(params, carry, tok)
            if do_arm:
                carry = win_arm(carry)
            if do_collect:
                carry, report = win_collect(carry)
            else:
                report = eng.zero_report()
            return carry, out, report

        # the decode carry (KV pool + last tokens) is DONATED: each
        # window updates the paged pool in place instead of
        # double-buffering it per dispatch. params (argnum 0) are NOT
        # donated — they are reused every call. The server never touches
        # a carry after passing it in (self.state is reassigned from the
        # returned carry; tests/test_donation.py).
        self._win_aligned = jax.jit(aligned, donate_argnums=(1,))
        self._win_generic = jax.jit(generic, donate_argnums=(1,))
        self._step_apply = jax.jit(
            step_apply, static_argnames=("do_arm", "do_collect"),
            donate_argnums=(1,))

    # -- one decode step across the batch -------------------------------------
    def decode_step(self, params, tokens: jax.Array
                    ) -> Tuple[jax.Array, None]:
        """tokens: [B] -> (logits [B, V], None). ONE dispatch: the model
        step plus — statically, from the host-side window clock — the ATC
        arm and the fused collect+MIAD+backend. The per-step reference
        for `decode_window` (bit-identical transitions)."""
        nxt = self._steps + 1
        every = self.cfg.collect_every
        do_arm = bool(self.cfg.overlap_collect) and \
            nxt % every == every - 1
        do_collect = nxt % every == 0
        carry = {"kv": self.state, "tok": self._last_tok}
        carry, out, report = self._step_apply(
            params, carry, jnp.asarray(tokens, jnp.int32),
            do_arm=do_arm, do_collect=do_collect)
        self.state, self._last_tok = carry["kv"], carry["tok"]
        self._steps += 1
        self.dispatches += 1
        if do_collect:
            self.reports.append({k: float(v) for k, v in report.items()})
        return out["logits"], None

    # -- scanned decode windows ------------------------------------------------
    def decode_window(self, params, tokens: jax.Array,
                      w: Optional[int] = None):
        """Run a whole decode window as ONE dispatch.

        tokens: [B, T] int32 — entries >= 0 are teacher-forced, entries
        < 0 self-feed the previously sampled token; or [B] (a seed token
        per sequence) with `w` given, running `w` steps (seed then
        self-feed). Every step embeds, runs all layers (paged append +
        attend), computes logits and samples; window-closing steps run
        the fused collect+MIAD+backend in the same program (and, with
        overlap_collect, arm the ATC epoch one step earlier). Uses the
        cond-free window-aligned program when T and the op clock align
        with collect_every, the generic cond-gated one otherwise.

        Returns (logits [B, T, V], sampled [B, T], per-step report
        pytree — feed to engine.window_reports to extract the collects)."""
        toks = jnp.asarray(tokens, jnp.int32)
        if toks.ndim == 1:
            toks = jnp.concatenate(
                [toks[:, None],
                 jnp.full((toks.shape[0], (w or 1) - 1), -1, jnp.int32)],
                axis=1)
        toks = toks.T                                   # scan axis first
        t = int(toks.shape[0])
        every = self.cfg.collect_every
        carry = {"kv": self.state, "tok": self._last_tok}
        if t > 0 and t % every == 0 and self._steps % every == 0:
            carry, outs, reports = self._win_aligned(params, carry, toks)
        else:
            carry, outs, reports = self._win_generic(params, carry, toks,
                                                     self._steps)
        self.state, self._last_tok = carry["kv"], carry["tok"]
        self._steps += t
        self.dispatches += 1
        return (outs["logits"].transpose(1, 0, 2), outs["tok"].T, reports)

    # -- generate --------------------------------------------------------------
    def generate(self, params, prompts: jax.Array, max_new: int,
                 *, greedy: bool = True, key=None) -> jax.Array:
        """prompts: [B, P], teacher-forced through the same scanned decode
        path (prefill exercises HADES on the prefix blocks), then
        `max_new` greedy tokens — window-by-window (W = cfg.window or
        collect_every), O(tokens / W) dispatches.

        With overlap_collect the loop is double-buffered: window N's
        report sync (the only host<->device round trip) happens only
        after window N+1's dispatch is in flight, so collection resolves
        while the next window decodes."""
        b, p = prompts.shape
        if max_new <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        total = p + max_new - 1
        forced = jnp.concatenate(
            [jnp.asarray(prompts, jnp.int32),
             jnp.full((b, max_new - 1), -1, jnp.int32)], axis=1)
        w = self.cfg.window or self.cfg.collect_every
        sampled = []
        pending = None
        for lo in range(0, total, w):
            _, toks, rep = self.decode_window(params, forced[:, lo:lo + w])
            sampled.append(toks)
            if self.cfg.overlap_collect:
                if pending is not None:
                    self.reports.extend(eng.window_reports(pending))
                pending = rep
            else:
                self.reports.extend(eng.window_reports(rep))
        if pending is not None:
            self.reports.extend(eng.window_reports(pending))
        out = jnp.concatenate(sampled, axis=1)          # [B, total]
        return out[:, p - 1:]

    def reset(self) -> None:
        """Fresh serving state (empty pool, zeroed clock/reports) without
        dropping the compiled programs — shapes are geometry-only, so
        benchmarks and multi-request drivers restart instantly."""
        self.state = kvc.init(self.kv_cfg, backend=self.backend)
        self._steps = 0
        self._last_tok = jnp.zeros((self.cfg.batch,), jnp.int32)
        self.reports = []
        self.dispatches = 0

    # -- metrics -----------------------------------------------------------------
    def kv_rss_bytes(self) -> float:
        return float(pl.rss_bytes(self.kv_cfg.pool_config(),
                                  self.state["pool"]))
