from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.runtime.server import Server, ServerConfig  # noqa: F401
