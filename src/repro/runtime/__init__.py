from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.runtime.server import (Completion, Request, Server,  # noqa: F401
                                  ServerConfig)
