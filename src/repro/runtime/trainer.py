"""Fault-tolerant training loop.

Production properties (designed for 1000+ nodes, exercised at CPU scale):
  * resume-exact: deterministic data (TokenPipeline.batch_at(step)) +
    checkpointed (params, opt, step, rng) -> any step is replayable;
  * preemption-safe: SIGTERM/SIGINT triggers a final synchronous
    checkpoint before exit (the Borg/TPU maintenance-event pattern);
  * async checkpointing every ckpt_every steps with atomic commit;
  * straggler monitor: per-step wall time EWMA; steps slower than
    `straggler_factor` x EWMA are logged — on a real fleet this feeds
    the scheduler's hot-spare swap; here it is surfaced in metrics;
  * elastic restore: checkpoints are unsharded; restoring on a
    different mesh re-shards (checkpoint/ckpt.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.lm import DataConfig, TokenPipeline
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma: float = 0.9


class Trainer:
    def __init__(self, model, data_cfg: DataConfig,
                 opt_cfg: adamw.AdamWConfig, run_cfg: TrainerConfig,
                 loss_fn: Optional[Callable] = None):
        self.model = model
        self.data = TokenPipeline(data_cfg)
        self.opt_cfg = opt_cfg
        self.cfg = run_cfg
        self.ckpt = ckpt_lib.Checkpointer(run_cfg.ckpt_dir,
                                          keep_last=run_cfg.keep_last)
        self._preempted = False
        self._step_ewma: Optional[float] = None
        self.straggler_events = []
        loss = loss_fn or (lambda p, b: model.loss(p, b)[0])

        def train_step(params, opt_state, batch):
            lval, grads = jax.value_and_grad(loss)(params, batch)
            params, opt_state, metrics = adamw.adamw_update(
                opt_cfg, params, grads, opt_state)
            metrics["loss"] = lval
            return params, opt_state, metrics
        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # -- preemption ----------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # -- run -------------------------------------------------------------------
    def run(self, params: Any, num_steps: int, *,
            start_step: Optional[int] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None
            ) -> Dict:
        """Train; resumes from the latest checkpoint if one exists."""
        opt_state = adamw.adamw_init(params)
        step = 0
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if start_step is None and latest is not None:
            tree = ckpt_lib.restore(self.cfg.ckpt_dir, latest,
                                    {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            step = latest
        elif start_step is not None:
            step = start_step

        history = []
        while step < num_steps and not self._preempted:
            t0 = time.perf_counter()
            batch = self.data.batch_at(step)
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch)
            metrics["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            step += 1

            # straggler detection
            if self._step_ewma is None:
                self._step_ewma = dt
            else:
                if dt > self.cfg.straggler_factor * self._step_ewma:
                    self.straggler_events.append((step, dt, self._step_ewma))
                self._step_ewma = (self.cfg.ewma * self._step_ewma
                                   + (1 - self.cfg.ewma) * dt)

            if step % self.cfg.log_every == 0 or step == num_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = dt
                history.append((step, m))
                if on_metrics:
                    on_metrics(step, m)
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step, {"params": params,
                                            "opt": opt_state},
                                     extra={"step": step})

        # preemption or completion: final synchronous checkpoint
        self.ckpt.wait()
        ckpt_lib.save(self.cfg.ckpt_dir, step,
                      {"params": params, "opt": adamw_state_host(opt_state)},
                      extra={"step": step,
                             "preempted": bool(self._preempted)},
                      keep_last=self.cfg.keep_last)
        return {"params": params, "opt": opt_state, "step": step,
                "history": history,
                "stragglers": list(self.straggler_events),
                "preempted": self._preempted}


def adamw_state_host(opt_state):
    return opt_state
