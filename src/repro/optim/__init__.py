from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa: F401
                               cosine_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,  # noqa: F401
                                     compressed_allreduce)
