"""AdamW with decoupled weight decay, global-norm clipping, and a cosine
schedule with linear warmup. Optimizer state is a plain pytree (m, v in
fp32 regardless of param dtype — the standard mixed-precision recipe), so
it shards with the params under pjit (FSDP: state inherits the param
PartitionSpec) and checkpoints with the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: Dict
                 ) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gn}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
