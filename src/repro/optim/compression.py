"""Gradient compression for cross-pod all-reduce: int8 block quantization
with error feedback.

At 512+ chips the pod-axis gradient all-reduce crosses DCI links an order
of magnitude slower than ICI; 4x compression (bf16 -> int8) cuts that
term directly. Error feedback (residual carried into the next step)
keeps the quantization unbiased in the long run — SGD/Adam convergence
is preserved (1-bit Adam / PowerSGD lineage).

Layout: per 256-element block, scale = max|g| / 127; payload int8. The
all-reduce decompresses, sums, and recompresses only at pod boundaries
(jax.lax.psum over the decompressed fp32 is used here — the compression
targets the wire format; XLA fuses the conversions around the
collective).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g: any shape -> (q int8 [ceil(n/B)*B], scales fp32 [ceil(n/B)])."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_allreduce(grads: Any, axis_name: str,
                         error: Any = None) -> Tuple[Any, Any]:
    """Quantize -> psum -> dequantize with error feedback, per leaf.
    Returns (reduced_grads, new_error). Call inside shard_map/pjit with
    `axis_name` bound to the pod axis."""
    if error is None:
        error = jax.tree.map(jnp.zeros_like, grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = compress_int8(g32)
        local = decompress_int8(q, scale, g.shape, jnp.float32)
        new_e = (g32 - local).astype(e.dtype)          # residual feedback
        summed = jax.lax.psum(local, axis_name)
        return summed.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
