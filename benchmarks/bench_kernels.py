"""Kernel micro-benchmarks: the 5 Pallas kernels vs their jnp oracles.

NOTE on semantics: this container is CPU-only, so Pallas runs in
INTERPRET mode — wall times here validate plumbing cost, not TPU
performance (TPU perf is the §Roofline analysis). The oracle timing is
the XLA:CPU fused path; the derived column reports bytes touched so the
numbers can be sanity-checked against any machine's bandwidth.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def main(smoke: bool = False):
    rng = np.random.default_rng(0)
    s = 2 if smoke else 1

    # migrate
    data = jnp.asarray(rng.normal(size=(1024 // s, 256)).astype(np.float32))
    n_mv = 128 // s
    src = jnp.asarray(rng.choice(512 // s, n_mv, replace=False), jnp.int32)
    dst = jnp.asarray(512 // s + rng.choice(512 // s, n_mv, replace=False),
                      jnp.int32)
    ok = jnp.ones(n_mv, bool)
    us = timed(lambda: ops.migrate(data, src, dst, ok))
    us_ref = timed(lambda: ref.migrate(data, src, dst, ok))
    emit("kernel_migrate", us,
         f"ref_us={us_ref:.0f};moved_kib={n_mv*256*4/1024:.0f}")

    # access_scan
    from repro.core import object_table as ot
    n = 4096 // s
    tbl = ot.pack(jnp.arange(n, dtype=jnp.uint32) % 1024,
                  jnp.asarray(rng.integers(0, 3, n), jnp.uint32),
                  jnp.asarray(rng.integers(0, 2, n), jnp.uint32))
    ct = jnp.asarray(3, jnp.uint32)
    us = timed(lambda: ops.access_scan(tbl, ct, sb_slots=64, n_sbs=16))
    us_ref = timed(lambda: ref.access_scan(tbl, ct, 64, 16))
    emit("kernel_access_scan", us, f"ref_us={us_ref:.0f};objects={n}")

    # flash attention
    b, sq, h, kv, d = 1, 512 // s, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, sq, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, sq, kv, d)).astype(np.float32))
    us = timed(lambda: ops.flash_attention(q, k, v))
    us_ref = timed(lambda: ref.flash_attention(q, k, v))
    flops = 4 * b * h * sq * sq * d // 2
    emit("kernel_flash_attention", us,
         f"ref_us={us_ref:.0f};mflops={flops/1e6:.0f}")

    # paged attention
    n_slots, bt, mb = 64, 16, 8
    q1 = jnp.asarray(rng.normal(size=(4, h, d)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(n_slots, bt, kv, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n_slots, bt, kv, d)).astype(np.float32))
    tables = jnp.asarray(rng.integers(0, n_slots, (4, mb)), jnp.int32)
    lens = jnp.full((4,), bt * mb, jnp.int32)
    us = timed(lambda: ops.paged_attention(q1, kp, vp, tables, lens))
    us_ref = timed(lambda: ref.paged_attention(q1, kp, vp, tables, lens, bt))
    emit("kernel_paged_attention", us,
         f"ref_us={us_ref:.0f};kv_kib={4*mb*bt*kv*d*2*4/1024:.0f}")

    # mamba scan
    a = jnp.asarray(rng.uniform(0.5, 1, (2, 256 // s, 16, 16))
                    .astype(np.float32))
    bb = jnp.asarray(rng.normal(size=(2, 256 // s, 16, 16))
                     .astype(np.float32))
    h0 = jnp.zeros((2, 16, 16), jnp.float32)
    us = timed(lambda: ops.mamba_scan(a, bb, h0))
    us_ref = timed(lambda: ref.mamba_scan(a, bb, h0))
    emit("kernel_mamba_scan", us,
         f"ref_us={us_ref:.0f};state_kib={2*16*16*4/1024:.1f}")


if __name__ == "__main__":
    main()
