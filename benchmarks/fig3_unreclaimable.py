"""Fig. 3 — Unreclaimable memory: RSS vs touched pages vs touched bytes.

The paper's Redis/YCSB-C gap: 1.2 GiB resident while only ~0.5 MiB of
cachelines are actually touched. Reproduced on CrestKV/hash-pugh: the
ratio RSS : touched-page bytes : unique touched bytes quantifies how
much memory page-granular reclamation CANNOT recover (the hotness-
fragmentation tax) without HADES.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_KEYS, emit, run_crest
from repro.core.simheap import PAGE


def main(smoke: bool = False):
    n = 30_000 if smoke else N_KEYS
    kv, stats, wall = run_crest("hash-pugh", "C", backend="null",
                                enabled=False, n_keys=n, n_ops=n * 10,
                                window=n * 5)
    # one observation window of zipfian traffic
    from repro.data.ycsb import ZipfianKeys
    kv.heap.access[:] = False
    z = ZipfianKeys(n, seed=11, active_frac=1 / 3)
    ks = z.sample(n)
    kv.heap.access_objects(kv.struct.touched(
        ks, np.zeros(len(ks), bool), kv.value_obj[ks]))
    rss = kv.heap.rss_bytes()
    touched_bytes = kv.heap.touched_bytes()
    pp = kv.heap.per_page_utilization()
    touched_page_bytes = len(pp) * PAGE
    gap = rss - touched_bytes
    emit("fig3_unreclaimable", wall * 1e6 / max(stats.ops, 1),
         f"rss_mib={rss/2**20:.1f};touched_pages_mib="
         f"{touched_page_bytes/2**20:.1f};"
         f"touched_bytes_mib={touched_bytes/2**20:.1f};"
         f"reclaimable_gap_mib={gap/2**20:.1f}")
    return {"rss": rss, "touched_pages": touched_page_bytes,
            "touched_bytes": touched_bytes}


if __name__ == "__main__":
    main()
