"""Table 1 — robustness across the ten concurrent data structures.

Each structure runs YCSB-A (the adversarial mix: 50% updates, NEW-heap
churn) under baseline and HADES; reported per structure: page-util gain,
memory reduction, tracking overhead. The paper's point: object-level
tracking works regardless of pointer-graph shape and concurrency scheme,
with overhead ordered by traversal complexity (hash < skiplist < tree).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit, run_crest, steady
from repro.data.structures import STRUCTURES


def main(smoke: bool = False, workload: str = "A"):
    n_keys = 25_000 if smoke else 60_000
    n_ops = n_keys * 12
    window = n_keys * 3
    out: List[Dict] = []
    for name in sorted(STRUCTURES):
        _, base, _ = run_crest(name, workload, backend="null",
                               enabled=False, n_keys=n_keys, n_ops=n_ops,
                               window=window)
        _, hades, wall = run_crest(name, workload, backend="proactive",
                                   enabled=True, n_keys=n_keys,
                                   n_ops=n_ops, window=window)
        r = {
            "structure": name,
            "pu_gain": steady(hades.windows, "page_utilization") /
            max(steady(base.windows, "page_utilization"), 1e-9),
            "mem_reduction": 1 - steady(hades.windows, "rss_bytes") /
            max(steady(base.windows, "rss_bytes"), 1.0),
            "overhead": hades.overhead_frac,
        }
        out.append(r)
        emit(f"table1_{name}", wall * 1e6 / max(hades.ops, 1),
             f"pu_gain={r['pu_gain']:.2f}x;"
             f"mem_red={r['mem_reduction']:.2f};"
             f"ovh={r['overhead']*100:.2f}%")
    return out


if __name__ == "__main__":
    main()
