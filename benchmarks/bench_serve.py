"""Serving decode-window benchmark: per-step vs scanned-window vs
double-buffered overlapped decode on the HADES paged-KV server. Emits
`BENCH_serve.json` via benchmarks.common.emit_json — the perf trajectory
artifact the acceptance gate reads (windowed decode must issue <= 2 host
dispatches per W-token window, vs W per-step, and >= 2x tokens/sec on
CPU at W=16).

    PYTHONPATH=src:. python benchmarks/bench_serve.py [--smoke]

All three variants run the IDENTICAL fused model transition (embed ->
per-layer qkv/paged-attend/ffn -> logits -> sample -> collect cadence);
the per-step path pays one host dispatch per token, the windowed path
one per W tokens (`Server.decode_window`, a single jitted lax.scan), and
the overlapped path additionally defers each window's report sync until
the next window's dispatch is in flight (the ATC/arm epoch protocol
keeps migration safe while steps are conceptually in flight).

Dispatch accounting is host-side and exact (`Server.dispatches`).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json
from repro.models.model import build
from repro.runtime.server import Server, ServerConfig


def _run_per_step(srv, params, toks):
    done = None
    for t in range(toks.shape[1]):
        logits, _ = srv.decode_step(params, toks[:, t])
        # the host round trip a per-token loop cannot avoid: the
        # scheduler inspects the sampled token every step (EOS, lane
        # refill) before it can issue the next one — exactly the sync
        # the scanned window amortizes to once per W tokens
        done = bool((np.asarray(srv._last_tok)
                     == srv.cfg.eos_token).all())
    jax.block_until_ready(logits)
    return done


def _run_windowed(srv, params, toks):
    # the production entry point: teacher-force every token through
    # `generate` (max_new=1 -> total steps == n_tokens), which chunks
    # into W-step decode_window dispatches and — with overlap_collect —
    # runs the double-buffered report-sync loop itself
    out = srv.generate(params, toks, max_new=1)
    jax.block_until_ready(out)


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(smoke: bool = False):
    w = 16
    n_tokens = 2 * w if smoke else 6 * w
    # container timers are noisy and the per-step variant syncs every
    # token (hypersensitive to scheduler jitter): best-of over enough
    # repeats that each variant sees a quiet window
    repeats = 2 if smoke else 6
    # small-batch decode: the latency-critical serving regime, where the
    # per-token host dispatch + sync overhead the windowed scan removes
    # is the dominant cost (large batches amortize it on compute)
    batch = 2
    m = build("chatglm3-6b", reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # the pool is sized to the run (Server.reset between repeats reuses
    # the compiled programs) — an oversized max_len would inflate every
    # step's pool traffic and hide the dispatch-overhead story
    kw = dict(batch=batch, max_len=n_tokens + w, block_tokens=w,
              collect_every=w, window=w)
    toks = jnp.asarray(rng.integers(0, m.cfg.vocab_size,
                                    (batch, n_tokens)), jnp.int32)

    record = {"arch": "chatglm3-6b-reduced", "smoke": smoke,
              "batch": batch, "window": w, "n_tokens": n_tokens,
              "collect_every": w}
    variants = [
        ("per_step", False, _run_per_step, ()),
        ("windowed", False, _run_windowed, ()),
        ("overlapped", True, _run_windowed, ()),
    ]
    for tag, overlap, fn, extra in variants:
        srv = Server(m, ServerConfig(overlap_collect=overlap, **kw))
        fn(srv, params, toks, *extra)          # warmup (compile)
        n_disp = None
        secs = float("inf")
        for _ in range(repeats):
            srv.reset()
            t0 = time.perf_counter()
            fn(srv, params, toks, *extra)
            secs = min(secs, time.perf_counter() - t0)
            n_disp = srv.dispatches
        toks_total = batch * n_tokens
        record[f"{tag}_tokens_per_sec"] = toks_total / secs
        record[f"{tag}_dispatches_per_token"] = n_disp / n_tokens
        record[f"{tag}_dispatches_per_window"] = n_disp / (n_tokens / w)
    record["windowed_speedup"] = (record["windowed_tokens_per_sec"]
                                  / record["per_step_tokens_per_sec"])
    record["overlapped_speedup"] = (record["overlapped_tokens_per_sec"]
                                    / record["per_step_tokens_per_sec"])
    # smoke runs (CI) go to scratch so they never clobber the committed
    # full-run perf-trajectory artifact; merge=True preserves the
    # continuous-batching row bench_continuous.py contributes
    out_dir = "bench_out" if smoke else "."
    emit_json("serve", record, out_dir=out_dir, merge=True)
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
