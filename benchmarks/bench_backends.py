"""Backend sweep on the production engine (Figure-7-style): every
registered tiering backend x workload -> RSS saved vs overhead, with
host-side dispatch accounting proving the stateful backends (mglru,
promote) run INSIDE the fused serving window (1 dispatch per window,
same as the stateless ones). Emits `BENCH_backends.json` via
benchmarks.common.emit_json — a perf-trajectory artifact.

    PYTHONPATH=src:. python benchmarks/bench_backends.py [--smoke]

Workloads (each window = `every` batched ops, K object ids per op):

  zipf    a scattered hot eighth is hammered with reads; the rest cools
          — the paper's skewed-serving case where tidying + any
          demoting backend should cut RSS at ~zero fault cost.
  phase   three phases: hot set A (densified into HOT superblocks), a
          long detour to set B (A cools and gets demoted IN PLACE in
          the HOT region), then STORES to A. Stores neither fault nor
          migrate (A's heap is already HOT), so only a page-level
          promoter re-tiers A — the case the promote backend exists
          for; every other backend leaves the written-hot set in slow
          memory.
  scan    a rotating sequential sweep touches everything eventually —
          the anti-LRU adversary where hotness-blind eviction (cap)
          thrashes.

Reported per cell: steady RSS fraction of the footprint, wall time per
window, faults, backend demote/promote totals, dispatches per window
(asserted == 1: the fused-window contract is backend-independent).
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json
from repro.core import HadesOptions, make_config
from repro.core import backend as be
from repro.core import engine as eng
from repro.core import object_table as ot
from repro.core.collector import CollectorConfig

EVERY, K = 16, 64


def end_load_phase(state):
    """Clear load-time access bits + window counters (allocation stores
    are not workload accesses) — what `Hades.end_load_phase` / CrestKV's
    load do, so the run starts with a fresh observation window."""
    return dict(state,
                table=ot.clear_access_and_atc(state["table"]),
                slot_ref=jnp.zeros_like(state["slot_ref"]),
                win_accesses=jnp.zeros((), jnp.int32),
                win_promos=jnp.zeros((), jnp.int32),
                win_faults=jnp.zeros((), jnp.int32))


def build_trace(cfg, workload: str, n_windows: int, rng):
    n = cfg.max_objects
    steps = []
    perm = rng.permutation(n)
    n_a = max(n // 8, K)
    set_a = perm[:n_a]
    set_b = perm[n_a:n_a + n // 4]    # disjoint from A: the detour must
    # not fault A's superblocks back in
    wvals = rng.normal(size=(K, cfg.slot_words)).astype(np.float32)
    for t in range(n_windows * EVERY):
        w = t // EVERY
        if workload == "zipf":
            steps.append(("read", set_a[rng.integers(0, len(set_a), K)],
                          None))
        elif workload == "phase":
            # detour is SHORT (3 windows): long enough for pressure to
            # demote A's now-idle superblocks, short enough that A stays
            # in the HOT heap (ciw <= C_t) — so the write phase hits
            # HOT-heap objects on HOST superblocks, the page-level
            # promotion case no frontend migration can cover
            build = max(n_windows // 4, 1)
            if w < build:                           # build: A densifies
                steps.append(("read",
                              set_a[rng.integers(0, len(set_a), K)], None))
            elif w < build + 3:                     # detour: A demoted
                steps.append(("read",
                              set_b[rng.integers(0, len(set_b), K)], None))
            else:                                   # stores to cold-hot A
                steps.append(("write",
                              set_a[rng.integers(0, len(set_a), K)],
                              wvals))
        else:  # scan: rotating sequential sweep
            lo = (t * K) % n
            ids = (np.arange(lo, lo + K)) % n
            steps.append(("read", ids, None))
    return eng.make_trace(cfg, steps, k=K)


def run_windows(engine, state, trace):
    """Window-by-window streaming (the serving shape): one dispatch per
    window, reports pulled between dispatches. The engine DONATES its
    state input (in-place pool updates), so each run works on a private
    copy and the caller's `state` stays alive for the next repeat."""
    state = jax.tree.map(lambda x: x.copy(), state)
    t = int(trace["op"].shape[0])
    dispatches = 0
    reports = []
    for lo in range(0, t, EVERY):
        chunk = {k2: v[lo:lo + EVERY] for k2, v in trace.items()}
        state, _, rep = engine.run_window(state, chunk, lo)
        reports.extend(eng.window_reports(rep))
        dispatches += 1
    jax.block_until_ready(state["table"])
    return state, reports, dispatches


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(smoke: bool = False):
    n_objects = 1024
    n_windows = 8 if smoke else 16
    repeats = 1 if smoke else 3
    cfg = make_config(max_objects=n_objects, slot_words=32, sb_slots=64,
                      page_slots=8, slack=1.5)
    rng = np.random.default_rng(0)

    # pressure target: half the allocated footprint (sb-aligned)
    footprint_sbs = -(-n_objects // cfg.sb_slots)
    target = (footprint_sbs // 2) * cfg.sb_bytes
    systems = {
        "null": be.make("null"),
        "reactive": be.make("reactive", hbm_target_bytes=target),
        "proactive": be.make("proactive"),
        "cap": be.make("cap", hbm_target_bytes=target),
        "mglru": be.make("mglru", hbm_target_bytes=target),
        "promote": be.make("promote", hbm_high_bytes=target,
                           hbm_low_bytes=target // 2),
    }
    assert set(systems) == set(be.names()), "sweep must cover the registry"

    record = {"n_objects": n_objects, "collect_every": EVERY,
              "ops_per_step": K, "n_windows": n_windows,
              "hbm_target_bytes": target, "smoke": smoke}
    vals = rng.normal(size=(n_objects, cfg.slot_words)).astype(np.float32)
    footprint = float(footprint_sbs * cfg.sb_bytes)

    for workload in ("zipf", "phase", "scan"):
        # per-workload deterministic stream: cells are reproducible in
        # isolation and don't shift when the sweep order changes
        trace = build_trace(cfg, workload, n_windows,
                            np.random.default_rng(0))
        for name, backend in systems.items():
            opts = HadesOptions(collect_every=EVERY, backend=backend,
                                collector=CollectorConfig())
            engine = eng.Engine(cfg, opts)
            base, _, _ = engine.step(engine.init(), "alloc",
                                     np.arange(n_objects), vals)
            base = end_load_phase(base)
            jax.block_until_ready(base["table"])

            state, reports, dispatches = run_windows(engine, base, trace)
            secs = _best_of(lambda: run_windows(engine, base, trace),
                            repeats)
            # host-side compiled-program launches (same accounting as
            # bench_serve's Server.dispatches): one run_window call per
            # window, every backend — the stateful ones compile into the
            # SAME single window program (their bstate is scan-carried;
            # a backend that needed a host round-trip would fail at
            # trace time, not add launches)
            dpw = dispatches / n_windows
            assert dpw == 1.0, \
                f"{name}: backend broke the fused window ({dpw} disp/win)"
            tail = reports[-max(n_windows // 4, 1):]
            cell = {
                "rss_frac": float(np.mean([r["rss_bytes"] for r in tail]))
                / footprint,
                "us_per_window": secs / n_windows * 1e6,
                "dispatches_per_window": dpw,
                "faults": int(state["total_faults"]),
                "demoted": int(sum(r["be_demoted"] for r in reports)),
                "promoted": int(sum(r["be_promoted"] for r in reports)),
            }
            record[f"{workload}_{name}"] = cell
            print(f"{workload:6s} {name:9s} rss={cell['rss_frac']:.2f} "
                  f"faults={cell['faults']:4d} "
                  f"dem={cell['demoted']:4d} prom={cell['promoted']:3d} "
                  f"{cell['us_per_window']:8.0f} us/win")

    out_dir = "bench_out" if smoke else "."
    os.makedirs(out_dir, exist_ok=True)
    emit_json("backends", record, out_dir=out_dir)
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
