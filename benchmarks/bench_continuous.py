"""Continuous-batching serving benchmark: lane churn through the fused
decode windows at exactly ONE dispatch per window.

A zipf'd request mix (short prompts dominate, a heavy tail of long
generations) drives `Server.serve`: lanes admit from the queue, decode,
finish on EOS/max-tokens and FREE their KV through the pool op stream at
the next window boundary — the realistic generator of the paper's
hotness fragmentation (finished requests strand cold blocks interleaved
with live lanes' hot blocks across superblocks). Emits the
continuous-batching row into `BENCH_serve.json` (merged with
bench_serve.py's per-step/windowed/overlapped rows):

  * tokens/sec over the whole churn run,
  * per-window KV-RSS vs live-bytes curves (RSS must TRACK live bytes
    via post-finish reclamation, not ride at peak allocation),
  * reclaimed-after-finish accounting.

In-script asserts (CI runs --smoke): exactly 1 dispatch per window, and
nonzero post-finish reclamation (final RSS < peak RSS).

    PYTHONPATH=src:. python benchmarks/bench_continuous.py [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit_json
from repro.models.model import build
from repro.runtime.server import Request, Server, ServerConfig


def _requests(n: int, rng: np.random.Generator, vocab: int,
              max_len: int) -> list:
    """Zipf'd request mix: prompt and output lengths are heavy-tailed,
    so lanes finish at very different times (the arrival churn a
    continuous batcher exists for)."""
    reqs = []
    for _ in range(n):
        p_len = int(np.clip(rng.zipf(1.8), 2, 10))
        max_new = int(np.clip(4 * rng.zipf(1.6), 4, max_len - p_len - 1))
        prompt = rng.integers(0, vocab, (p_len,)).tolist()
        reqs.append(Request(prompt=prompt, max_new=max_new))
    return reqs


def main(smoke: bool = False):
    w = 8
    batch = 4
    max_len = 64
    n_req = 10 if smoke else 32
    m = build("chatglm3-6b", reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    srv = Server(m, ServerConfig(batch=batch, max_len=max_len,
                                 block_tokens=w, collect_every=w,
                                 window=w))
    reqs = _requests(n_req, rng, m.cfg.vocab_size, max_len)

    srv.serve(params, _requests(2, rng, m.cfg.vocab_size, max_len))
    t0 = time.perf_counter()
    results = srv.serve(params, reqs)       # warm: programs compiled
    wall = time.perf_counter() - t0

    n_windows = len(srv.serve_log)
    # the fused-window contract under lane churn: every window — lane
    # frees, admits, prompt forcing, sampling, collect+backend — was
    # exactly one host dispatch
    assert srv.dispatches == n_windows, \
        f"{srv.dispatches} dispatches for {n_windows} windows"
    assert all(r is not None and r.tokens for r in results)

    rss = [e["rss_bytes"] for e in srv.serve_log]
    live = [e["live_bytes"] for e in srv.serve_log]
    peak, final = max(rss), rss[-1]
    # finished lanes' KV left the pool through the op stream and the
    # collector/backend reclaimed the emptied superblocks: RSS tracks
    # live bytes down, it does not ride at peak allocation
    assert peak > 0 and final < peak, \
        f"no post-finish reclamation: peak={peak} final={final}"
    assert live[-1] == 0.0, "drain window left live KV objects behind"

    toks_total = sum(len(r.tokens) for r in results)
    record = {"continuous": {
        "arch": "chatglm3-6b-reduced", "smoke": smoke, "batch": batch,
        "window": w, "max_len": max_len, "n_requests": n_req,
        "n_windows": n_windows,
        "dispatches_per_window": srv.dispatches / n_windows,
        "tokens_per_sec": toks_total / wall,
        "generated_tokens": toks_total,
        "finished_eos": sum(r.finish_reason == "eos" for r in results),
        "rss_peak_bytes": peak, "rss_final_bytes": final,
        "reclaimed_after_finish_bytes": peak - final,
        "rss_curve": rss, "live_curve": live,
    }}
    out_dir = "bench_out" if smoke else "."
    emit_json("serve", record, out_dir=out_dir, merge=True)
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
