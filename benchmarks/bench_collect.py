"""Window-throughput benchmark: fused single-dispatch serving windows
(engine.run_window) vs. the per-op dispatch path (Hades loop). Emits
`BENCH_collect.json` via benchmarks.common.emit_json — the perf
trajectory artifact the acceptance gate reads (fused/unfused window
speedup on CPU, target >= 3x).

    PYTHONPATH=src:. python benchmarks/bench_collect.py [--smoke] [--pallas]

Default scale sits in the serving regime the fusion targets: small
per-op metadata batches where host dispatch dominates compute, so one
program per window beats one program per op. `--pallas` additionally
times the use_pallas collector — on CPU that measures *interpret-mode
emulation* of the kernels (orders of magnitude slower than compiled),
so it is opt-in and excluded from the headline speedup.

Dispatch accounting is host-side and exact: the per-op path launches one
compiled program per op (collect fused into the window-closing op); the
fused path launches ONE program per window regardless of window length.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit_json
from repro.core import HadesOptions, make_config
from repro.core import backend as be
from repro.core import engine as eng
from repro.core.collector import CollectorConfig


def build_trace(cfg, rng, n_windows: int, every: int, k: int):
    """Zipf-ish serving trace: a scattered hot set is hammered, the rest
    decays cold — the collector has real work every window."""
    n = cfg.max_objects
    hot = rng.permutation(n)[:max(n // 8, k)]
    steps = []
    vals = rng.normal(size=(k, cfg.slot_words)).astype(np.float32)
    for t in range(n_windows * every):
        if t % every == every - 1:
            steps.append(("write", hot[rng.integers(0, len(hot), k)],
                          vals))
        else:
            steps.append(("read", hot[rng.integers(0, len(hot), k)], None))
    return eng.make_trace(cfg, steps, k=k), steps


def run_per_op(engine, state, steps, every):
    """The unfused path: one dispatch per op (what `Hades` does)."""
    dispatches = 0
    for i, (op, ids, values) in enumerate(steps):
        do_collect = (i + 1) % every == 0
        state, _, _ = engine.step(state, op, ids, values,
                                  do_collect=do_collect)
        dispatches += 1
    jax.block_until_ready(state["table"])
    return state, dispatches


def run_fused(engine, state, trace, every):
    t = int(trace["op"].shape[0])
    dispatches = 0
    for lo in range(0, t, every):
        chunk = {k: v[lo:lo + every] for k, v in trace.items()}
        state, _, _ = engine.run_window(state, chunk, lo)
        dispatches += 1
    jax.block_until_ready(state["table"])
    return state, dispatches


def _best_of(fn, repeats: int) -> float:
    """Best-of-N wall time (this container's timers are noisy; the min is
    the least-contended run)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(smoke: bool = False, with_pallas: bool = False):
    n_objects, every, k = 1024, 16, 64
    n_windows = 4 if smoke else 16
    repeats = 2 if smoke else 3
    cfg = make_config(max_objects=n_objects, slot_words=32, sb_slots=64,
                      page_slots=8, slack=1.5)
    rng = np.random.default_rng(0)
    trace, steps = build_trace(cfg, rng, n_windows, every, k)

    record = {"n_objects": n_objects, "slot_words": cfg.slot_words,
              "collect_every": every, "ops_per_step": k,
              "n_windows": n_windows}
    variants = [(False, "jnp")] + ([(True, "pallas")] if with_pallas else [])
    for use_pallas, tag in variants:
        opts = HadesOptions(collect_every=every,
                            backend=be.make("proactive"),
                            collector=CollectorConfig(use_pallas=use_pallas))
        engine = eng.Engine(cfg, opts)
        vals = rng.normal(size=(n_objects, cfg.slot_words)).astype(
            np.float32)
        base, _, _ = engine.step(engine.init(), "alloc",
                                 np.arange(n_objects), vals)
        jax.block_until_ready(base["table"])

        # warmup (compile both paths), then timed best-of runs
        run_per_op(engine, base, steps[:every], every)
        run_fused(engine, base, {k2: v[:every] for k2, v in trace.items()},
                  every)
        _, d_unfused = run_per_op(engine, base, steps, every)
        _, d_fused = run_fused(engine, base, trace, every)
        unfused_s = _best_of(lambda: run_per_op(engine, base, steps, every),
                             repeats)
        fused_s = _best_of(lambda: run_fused(engine, base, trace, every),
                           repeats)

        record[f"{tag}_unfused_us_per_window"] = unfused_s / n_windows * 1e6
        record[f"{tag}_fused_us_per_window"] = fused_s / n_windows * 1e6
        record[f"{tag}_unfused_dispatches_per_window"] = d_unfused / n_windows
        record[f"{tag}_fused_dispatches_per_window"] = d_fused / n_windows
        record[f"{tag}_window_speedup"] = unfused_s / fused_s

    emit_json("collect", record)
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, with_pallas="--pallas" in sys.argv)
