"""Window-throughput benchmark: fused single-dispatch serving windows
(engine.run_window) vs. the per-op dispatch path (Hades loop), plus the
pool-size scaling sweep that proves per-op cost is COMPUTE-PROPORTIONAL
(O(K) in the batch, independent of pool size — the carried free-list
allocator + incremental occupancy). Emits `BENCH_collect.json` via
benchmarks.common.emit_json — the perf trajectory artifact the
acceptance gate reads (fused/unfused window speedup on CPU, target
>= 3x; sweep µs/op growth 2048 -> 16384 objects, target <= 2x).

    PYTHONPATH=src:. python benchmarks/bench_collect.py [--smoke] [--pallas]

Default scale sits in the serving regime the fusion targets: small
per-op metadata batches where host dispatch dominates compute, so one
program per window beats one program per op. `--pallas` additionally
times the use_pallas collector — on CPU that measures *interpret-mode
emulation* of the kernels (orders of magnitude slower than compiled),
so it is opt-in and excluded from the headline speedup.

Dispatch accounting is host-side and exact: the per-op path launches one
compiled program per op (collect fused into the window-closing op); the
fused path launches ONE program per window regardless of window length.
The engine donates its state argument (in-place pool updates), so each
timed run starts from a private copy of the loaded pool.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit_json
from repro.core import HadesOptions, make_config
from repro.core import backend as be
from repro.core import engine as eng
from repro.core.collector import CollectorConfig


def _copy(state):
    """Private copy of a pool state (the engine donates its input)."""
    return jax.tree.map(lambda x: x.copy(), state)


def build_trace(cfg, rng, n_windows: int, every: int, k: int):
    """Zipf-ish serving trace: a scattered hot set is hammered, the rest
    decays cold — the collector has real work every window."""
    n = cfg.max_objects
    hot = rng.permutation(n)[:max(n // 8, k)]
    steps = []
    vals = rng.normal(size=(k, cfg.slot_words)).astype(np.float32)
    for t in range(n_windows * every):
        if t % every == every - 1:
            steps.append(("write", hot[rng.integers(0, len(hot), k)],
                          vals))
        else:
            steps.append(("read", hot[rng.integers(0, len(hot), k)], None))
    return eng.make_trace(cfg, steps, k=k), steps


def build_churn_trace(cfg, rng, n_windows: int, every: int, k: int):
    """Scaling-sweep trace: every window mixes reads with an alloc/free
    churn pair (free K live objects, alloc K fresh ids), so the sweep
    exercises the allocator fast path — the component that used to cost
    O(n_slots) per op — not just the access path."""
    n = cfg.max_objects
    hot = rng.permutation(n // 2)[:max(n // 8, k)]
    vals = rng.normal(size=(k, cfg.slot_words)).astype(np.float32)
    steps = []
    next_id = n // 2                     # ids n//2.. churn (never loaded)
    churned = []
    for t in range(n_windows * every):
        phase = t % every
        if phase == every - 2:
            if churned:
                steps.append(("free", np.asarray(churned[-1]), None))
            else:                        # first window: nothing to free yet
                steps.append(("read", hot[rng.integers(0, len(hot), k)],
                              None))
        elif phase == every - 1:
            ids = np.arange(next_id, next_id + k) % (n // 2) + n // 2
            next_id += k
            churned.append(ids)
            steps.append(("alloc", ids, vals))
        elif phase % 4 == 3:
            steps.append(("write", hot[rng.integers(0, len(hot), k)],
                          vals))
        else:
            steps.append(("read", hot[rng.integers(0, len(hot), k)], None))
    return eng.make_trace(cfg, steps, k=k)


def run_per_op(engine, state, steps, every):
    """The unfused path: one dispatch per op (what `Hades` does)."""
    state = _copy(state)
    dispatches = 0
    for i, (op, ids, values) in enumerate(steps):
        do_collect = (i + 1) % every == 0
        state, _, _ = engine.step(state, op, ids, values,
                                  do_collect=do_collect)
        dispatches += 1
    jax.block_until_ready(state["table"])
    return state, dispatches


def run_fused(engine, state, trace, every):
    state = _copy(state)
    t = int(trace["op"].shape[0])
    dispatches = 0
    for lo in range(0, t, every):
        chunk = {k: v[lo:lo + every] for k, v in trace.items()}
        state, _, _ = engine.run_window(state, chunk, lo)
        dispatches += 1
    jax.block_until_ready(state["table"])
    return state, dispatches


def _best_of(fn, repeats: int) -> float:
    """Best-of-N wall time (this container's timers are noisy; the min is
    the least-contended run)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _load(engine, cfg, rng, n_load):
    vals = rng.normal(size=(n_load, cfg.slot_words)).astype(np.float32)
    base, _, _ = engine.step(engine.init(), "alloc",
                             np.arange(n_load), vals)
    jax.block_until_ready(base["table"])
    return base


def headline(record, rng, smoke: bool, with_pallas: bool):
    """Fused vs per-op window throughput at the serving scale."""
    n_objects, every, k = 1024, 16, 64
    n_windows = 4 if smoke else 16
    repeats = 2 if smoke else 3
    cfg = make_config(max_objects=n_objects, slot_words=32, sb_slots=64,
                      page_slots=8, slack=1.5)
    trace, steps = build_trace(cfg, rng, n_windows, every, k)

    record.update({"n_objects": n_objects, "slot_words": cfg.slot_words,
                   "collect_every": every, "ops_per_step": k,
                   "n_windows": n_windows})
    variants = [(False, "jnp")] + ([(True, "pallas")] if with_pallas else [])
    for use_pallas, tag in variants:
        opts = HadesOptions(collect_every=every,
                            backend=be.make("proactive"),
                            collector=CollectorConfig(use_pallas=use_pallas))
        engine = eng.Engine(cfg, opts)
        base = _load(engine, cfg, rng, n_objects)

        # warmup (compile both paths), then timed best-of runs
        run_per_op(engine, base, steps[:every], every)
        run_fused(engine, base, {k2: v[:every] for k2, v in trace.items()},
                  every)
        _, d_unfused = run_per_op(engine, base, steps, every)
        _, d_fused = run_fused(engine, base, trace, every)
        unfused_s = _best_of(lambda: run_per_op(engine, base, steps, every),
                             repeats)
        fused_s = _best_of(lambda: run_fused(engine, base, trace, every),
                           repeats)

        record[f"{tag}_unfused_us_per_window"] = unfused_s / n_windows * 1e6
        record[f"{tag}_fused_us_per_window"] = fused_s / n_windows * 1e6
        record[f"{tag}_unfused_dispatches_per_window"] = d_unfused / n_windows
        record[f"{tag}_fused_dispatches_per_window"] = d_fused / n_windows
        record[f"{tag}_window_speedup"] = unfused_s / fused_s


def pool_size_sweep(record, smoke: bool):
    """Fixed-K sweep over pool size: with the carried free-list allocator
    and incremental occupancy, fused-window µs/op must stay near-flat as
    n_objects grows (the once-per-window collector sweep is the only
    O(n) component, amortized over `every` ops). Asserts the fused-window
    contract holds at every size: exactly 1 dispatch per window."""
    every, k = 32, 64
    sizes = [2048, 4096] if smoke else [2048, 4096, 8192, 16384]
    n_windows = 2 if smoke else 8
    repeats = 2 if smoke else 6   # container timers are noisy; min-of-6
    sweep = []
    for n_objects in sizes:
        cfg = make_config(max_objects=n_objects, slot_words=32,
                          sb_slots=64, page_slots=8, slack=1.5)
        rng = np.random.default_rng(7)
        trace = build_churn_trace(cfg, rng, n_windows, every, k)
        opts = HadesOptions(collect_every=every,
                            backend=be.make("proactive"),
                            collector=CollectorConfig())
        engine = eng.Engine(cfg, opts)
        base = _load(engine, cfg, rng, n_objects // 2)

        warm = {k2: v[:every] for k2, v in trace.items()}
        run_fused(engine, base, warm, every)                  # compile
        _, dispatches = run_fused(engine, base, trace, every)
        secs = _best_of(lambda: run_fused(engine, base, trace, every),
                        repeats)
        n_ops = n_windows * every
        point = {"n_objects": n_objects,
                 "fused_us_per_op": secs / n_ops * 1e6,
                 "fused_us_per_window": secs / n_windows * 1e6,
                 "dispatches_per_window": dispatches / n_windows}
        assert point["dispatches_per_window"] == 1.0, \
            f"n_objects={n_objects}: fused window broke " \
            f"({point['dispatches_per_window']} dispatches/window)"
        sweep.append(point)
        print(f"sweep n_objects={n_objects:6d} "
              f"{point['fused_us_per_op']:7.1f} us/op "
              f"{point['dispatches_per_window']:.0f} disp/win")
    record["sweep_collect_every"] = every
    record["sweep_ops_per_step"] = k
    record["sweep"] = sweep
    record["sweep_us_per_op_growth"] = (
        sweep[-1]["fused_us_per_op"] / sweep[0]["fused_us_per_op"])


def main(smoke: bool = False, with_pallas: bool = False):
    rng = np.random.default_rng(0)
    record = {}
    headline(record, rng, smoke, with_pallas)
    pool_size_sweep(record, smoke)
    emit_json("collect", record)
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, with_pallas="--pallas" in sys.argv)
