"""Embedding-tiering benchmark: zipfian token traffic through a
TieredEmbedding — how small an HBM-resident hot replica covers how much
of the lookup volume (the paper's hot/cold-region split applied to
vocab rows), and the cold-hit (promotion) rate the MIAD loop would see.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.models import embedding as emb


def main(smoke: bool = False):
    vocab, d = (8192, 64) if smoke else (32768, 128)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(vocab, d)).astype(np.float32))

    # zipfian token stream with scattered ids (data/lm.py semantics)
    w = 1.0 / np.power(np.arange(1, vocab + 1, dtype=np.float64), 1.1)
    cdf = np.cumsum(w) / np.sum(w)
    scramble = rng.permutation(vocab)

    def batch(k=8192):
        return jnp.asarray(
            scramble[np.searchsorted(cdf, rng.random(k))], jnp.int32)

    for hot_frac in (0.01, 0.05, 0.25):
        hot_rows = max(int(vocab * hot_frac), 1)
        cfg = emb.TieredEmbeddingConfig(vocab_size=vocab, d_model=d,
                                        hot_rows=hot_rows)
        s = emb.init(cfg, table)
        # warm the counts, re-elect, then measure steady-state cold rate
        for _ in range(4):
            _, s = emb.lookup(cfg, s, batch())
            s, rep = emb.collect(cfg, s)
        _, s = emb.lookup(cfg, s, batch())
        cold = float(s["win_cold_hits"]) / max(float(s["win_lookups"]), 1)
        us = timed(lambda: emb.lookup(cfg, s, batch())[0])
        hbm = emb.hbm_bytes(cfg, jnp.float32)
        total = emb.total_bytes(cfg, jnp.float32)
        emit(f"embedding_hot{int(hot_frac*100)}pct", us,
             f"cold_hit_rate={cold:.3f};hbm_frac={hbm/total:.3f};"
             f"coverage={float(rep['hot_coverage']):.3f}")


if __name__ == "__main__":
    main()
