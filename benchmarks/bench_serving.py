"""Serving-path benchmark: HADES paged-KV decode vs dense decode on a
reduced arch — validates the framework integration end-to-end (tokens/s
on CPU; the TPU projection is §Roofline) and reports KV RSS reduction
from collector-driven demotion of cold blocks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models.model import build
from repro.runtime.server import Server, ServerConfig


def main(smoke: bool = False):
    m = build("chatglm3-6b", reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    new_tokens = 24 if smoke else 64

    # dense decode baseline
    state = m.init_decode_state(4, 128)
    toks = jnp.asarray([1, 2, 3, 4], jnp.int32)
    step = jax.jit(m.decode_step)
    logits, state = step(params, state, toks)   # compile
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        logits, state = step(params, state, toks)
    logits.block_until_ready()
    dense_us = (time.perf_counter() - t0) / new_tokens * 1e6

    # HADES paged decode
    srv = Server(m, ServerConfig(batch=4, max_len=128, block_tokens=8,
                                 collect_every=16))
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, m.cfg.vocab_size, (4, 4)), jnp.int32)
    t0 = time.perf_counter()
    srv.generate(params, prompts, max_new=new_tokens)
    paged_us = (time.perf_counter() - t0) / (new_tokens + 4) * 1e6

    kv_total = float(srv.kv_cfg.max_objects * srv.kv_cfg.slot_words * 2)
    rss = srv.kv_rss_bytes()
    emit("serving_dense_decode", dense_us, "tokens=4/step")
    emit("serving_paged_hades", paged_us,
         f"kv_rss_frac={rss/max(kv_total,1):.2f};"
         f"collects={len(srv.reports)}")


if __name__ == "__main__":
    main()
