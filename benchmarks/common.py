"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.data.crestkv import CrestKV, default_sim_config

# default scale: finishes in seconds per cell; --full matches the paper's
# 10M keys (metadata-only, still laptop-feasible but minutes per cell)
N_KEYS = 120_000
N_OPS = 4_000_000
WINDOW = 600_000
FULL_N_KEYS = 10_000_000
FULL_N_OPS = 100_000_000


def run_crest(structure: str, workload: str, *, backend: str = "proactive",
              enabled: bool = True, n_keys: int = N_KEYS,
              n_ops: int = N_OPS, window: int = WINDOW,
              hbm_target_bytes: int = 0, seed: int = 0,
              active_frac: float = 1 / 3):
    cfg = default_sim_config(n_keys, backend=backend, enabled=enabled,
                             hbm_target_bytes=hbm_target_bytes)
    kv = CrestKV(structure, n_keys, cfg, seed=seed)
    t0 = time.perf_counter()
    stats = kv.run(workload, n_ops, window_ops=window, seed=seed + 1,
                   active_frac=active_frac)
    wall = time.perf_counter() - t0
    return kv, stats, wall


def steady(windows: List[Dict], key: str, tail: int = 4) -> float:
    """Mean of a metric over the last `tail` windows (steady state)."""
    xs = [w[key] for w in windows[-tail:]]
    return float(np.mean(xs)) if xs else float("nan")


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """us per call (after warmup, best-effort block_until_ready)."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """One CSV row on stdout: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(name: str, record: Dict, out_dir: str = ".",
              merge: bool = False) -> str:
    """Write one benchmark record to `BENCH_<name>.json` (the repo's perf
    trajectory artifacts) and echo it to stdout; creates `out_dir` if
    missing (smoke runs point at the gitignored `bench_out/` scratch
    dir). `merge=True` shallow-merges into an existing artifact instead
    of replacing it — how several benches share one file (bench_serve +
    bench_continuous both feed BENCH_serve.json). Returns the path."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    if merge and os.path.exists(path):
        with open(path) as fh:
            merged = json.load(fh)
        merged.update(record)
        record = merged
    with open(path, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"{name}: {json.dumps(record, sort_keys=True)}")
    return path
