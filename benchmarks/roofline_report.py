"""§Roofline harness: renders the per-cell roofline table from the
dry-run artifacts (launch/roofline.py does the math)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.launch import roofline


def main(smoke: bool = False):
    rows = roofline.load_all("experiments/dryrun", "pod256")
    if not rows:
        emit("roofline", 0.0, "no dry-run artifacts; run "
             "python -m repro.launch.dryrun --all --both-meshes")
        return []
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dom={r['dominant']};frac={r['roofline_frac']:.3f};"
             f"useful={r['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    main()
