"""Fig. 6 (a, b, c) — CrestKV x YCSB A/B/C: page-utilization improvement,
memory reduction, and performance overhead, baseline vs HADES.

Paper claims being validated:
  (a) page utilization improves ~2x (A), ~3x (B), ~4x/80% (C);
  (b) memory usage drops up to 70%;
  (c) overhead ~2.5% throughput / ~5% latency, varying by structure.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import N_KEYS, N_OPS, WINDOW, emit, run_crest, steady

STRUCTURES_6AB = ("hash-pugh",)            # fig 6a/b uses one structure/run
WORKLOADS = ("A", "B", "C")


def run_pair(structure: str, workload: str, *, n_keys: int, n_ops: int,
             window: int) -> Dict:
    _, base, wall_b = run_crest(structure, workload, backend="null",
                                enabled=False, n_keys=n_keys, n_ops=n_ops,
                                window=window)
    _, hades, wall_h = run_crest(structure, workload, backend="proactive",
                                 enabled=True, n_keys=n_keys, n_ops=n_ops,
                                 window=window)
    pu_b = steady(base.windows, "page_utilization")
    pu_h = steady(hades.windows, "page_utilization")
    rss_b = steady(base.windows, "rss_bytes")
    rss_h = steady(hades.windows, "rss_bytes")
    return {
        "structure": structure, "workload": workload,
        "pu_base": pu_b, "pu_hades": pu_h, "pu_gain": pu_h / pu_b,
        "rss_base": rss_b, "rss_hades": rss_h,
        "mem_reduction": 1 - rss_h / rss_b,
        "overhead": hades.overhead_frac,
        "latency_increase": hades.mean_latency_ns / base.mean_latency_ns - 1,
        "faults": hades.faults,
        "wall_us_per_op": wall_h * 1e6 / max(hades.ops, 1),
    }


def main(smoke: bool = False):
    n_keys = 40_000 if smoke else N_KEYS
    n_ops = n_keys * 60
    window = n_keys * 3
    out: List[Dict] = []
    for wl in WORKLOADS:
        for s in STRUCTURES_6AB:
            r = run_pair(s, wl, n_keys=n_keys, n_ops=n_ops, window=window)
            out.append(r)
            emit(f"fig6_{s}_{wl}", r["wall_us_per_op"],
                 f"pu={r['pu_base']:.2f}->{r['pu_hades']:.2f}"
                 f"({r['pu_gain']:.1f}x);mem_red={r['mem_reduction']:.2f};"
                 f"ovh={r['overhead']*100:.1f}%;"
                 f"lat=+{r['latency_increase']*100:.1f}%")
    return out


if __name__ == "__main__":
    main()
