"""Fig. 2 — Page Utilization CDFs for three KV-store analogs.

The paper instruments Redis / Memcached / MongoDB with PinTool; here the
same per-page utilization distribution comes from the SimHeap access log
of CrestKV over the structure each store actually uses (Table 1):
Redis -> hash-pugh, Memcached -> hash-chm (segmented locks), MongoDB ->
btree-occ. Reported: P50/P75/P90 per-page utilization + the paper's
reference points (Redis: 75% of pages <= 3%; others: 90% <= 15%).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_KEYS, emit, run_crest

STORES = {"redis": "hash-pugh", "memcached": "hash-chm",
          "mongodb": "btree-occ"}


def main(smoke: bool = False):
    n = 30_000 if smoke else N_KEYS
    rows = []
    for store, structure in STORES.items():
        kv, stats, wall = run_crest(structure, "C", backend="null",
                                    enabled=False, n_keys=n,
                                    n_ops=n * 10, window=n * 5)
        # leave access bits of the final window in place for the CDF
        kv.heap.access[:] = False
        from repro.data.ycsb import ZipfianKeys
        z = ZipfianKeys(n, seed=9, active_frac=1 / 3)
        ks = z.sample(n * 2)
        kv.heap.access_objects(kv.struct.touched(
            ks, np.zeros(len(ks), bool), kv.value_obj[ks]))
        pp = kv.heap.per_page_utilization()
        p50, p75, p90 = np.percentile(pp, [50, 75, 90])
        frac_below_15 = float((pp <= 0.15).mean())
        emit(f"fig2_{store}", wall * 1e6 / max(stats.ops, 1),
             f"p50={p50:.3f};p75={p75:.3f};p90={p90:.3f};"
             f"pages<=15%={frac_below_15:.2f}")
        rows.append((store, p50, p75, p90, frac_below_15))
    return rows


if __name__ == "__main__":
    main()
