"""Fig. 7 — Backend trade-off dissolution on YCSB-C.

Four systems over the same workload (12 GiB-footprint analog, ~1/3
active):
  cgroup-cap        memory-first: hard cap, hotness-blind eviction
                    -> hits hot pages, latency/throughput tank
  kswapd-pressure   performance-first: reactive reclaim under pressure
                    -> conservative, poor savings
  HADES + reactive  tidied address space, same kswapd backend
  HADES + proactive tidied + MADV_PAGEOUT once MIAD is calm

Reported per system: steady-state RSS, throughput degradation vs the
no-reclaim baseline, fault count. The paper's claim: HADES rows reach
the cap-level memory at ~zero performance cost.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import N_KEYS, emit, run_crest, steady


def main(smoke: bool = False):
    n_keys = 40_000 if smoke else N_KEYS
    n_ops = n_keys * 60
    window = n_keys * 3

    # footprint & target: active ~1/3 -> target cap at ~40% of footprint
    _, free_run, _ = run_crest("hash-pugh", "C", backend="null",
                               enabled=False, n_keys=n_keys, n_ops=n_ops,
                               window=window)
    footprint = steady(free_run.windows, "rss_bytes")
    target = int(footprint * 0.4)
    base_lat = free_run.mean_latency_ns

    systems = {
        "cgroup_cap": dict(backend="cap", enabled=False,
                           hbm_target_bytes=target),
        "kswapd_pressure": dict(backend="reactive", enabled=False,
                                hbm_target_bytes=target),
        "hades_reactive": dict(backend="reactive", enabled=True,
                               hbm_target_bytes=target),
        "hades_proactive": dict(backend="proactive", enabled=True),
        # the stateful registry backends ride the same SimHeap adapter
        "hades_mglru": dict(backend="mglru", enabled=True,
                            hbm_target_bytes=target),
        "hades_promote": dict(backend="promote", enabled=True,
                              hbm_target_bytes=target),
    }
    out: List[Dict] = []
    for name, kw in systems.items():
        _, st, wall = run_crest("hash-pugh", "C", n_keys=n_keys,
                                n_ops=n_ops, window=window, **kw)
        rss = steady(st.windows, "rss_bytes")
        slowdown = st.mean_latency_ns / base_lat - 1
        r = {"system": name, "rss_frac": rss / footprint,
             "target_frac": target / footprint,
             "slowdown": slowdown, "faults": st.faults}
        out.append(r)
        emit(f"fig7_{name}", wall * 1e6 / max(st.ops, 1),
             f"rss={rss/footprint:.2f}xfootprint;"
             f"slowdown={slowdown*100:.1f}%;faults={st.faults}")
    return out


if __name__ == "__main__":
    main()
