"""Benchmark aggregator: one run per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default scale finishes on a laptop CPU in ~10 minutes; --full uses
paper-scale key counts (minutes per cell, metadata-only memory).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_embedding, bench_kernels,
                        fig2_page_utilization,
                        fig3_unreclaimable, fig6_crestdb, fig7_backends,
                        roofline_report, table1_structures)

# serving benches live outside this CSV aggregator: bench_serve.py and
# bench_continuous.py emit the BENCH_serve.json perf-trajectory artifact
SUITES = [
    ("fig2_page_utilization", fig2_page_utilization.main),
    ("fig3_unreclaimable", fig3_unreclaimable.main),
    ("fig6_crestdb", fig6_crestdb.main),
    ("fig7_backends", fig7_backends.main),
    ("table1_structures", table1_structures.main),
    ("bench_kernels", bench_kernels.main),
    ("bench_embedding", bench_embedding.main),
    ("roofline_report", roofline_report.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    smoke = not args.full
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in SUITES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn(smoke=smoke)
            print(f"# {name} done in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
