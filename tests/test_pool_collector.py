"""HadesPool + Object Collector invariants (DESIGN.md §5), property-based.

1. slot uniqueness — no two live objects share a slot
2. content preservation — read-through value identical under any
   interleaving of collector passes
3. epoch safety — objects with ATC > 0 are never moved
4. heap coherence — table heap field matches the region of its slot
5. free-list coherence — each region's carried ring holds exactly its
   free slots (no dup, no leak, no live slot); counts match
6. occupancy coherence — carried `sb_occ` equals the O(n_slots) oracle
7. accounting conservation — a superblock is in exactly one tier
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dep (requirements-dev.txt) — only the property test
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import backend as be
from repro.core import collector as col
from repro.core import object_table as ot
from repro.core import pool as pl

CFG = pl.make_config(max_objects=64, slot_words=4, sb_slots=8,
                     page_slots=4, slack=2.0)
CCFG = col.CollectorConfig()


def fresh_pool(n_alloc=32):
    st_ = pl.init(CFG)
    vals = jnp.arange(n_alloc * 4, dtype=jnp.float32).reshape(n_alloc, 4)
    st_ = pl.alloc(CFG, st_, jnp.arange(n_alloc, dtype=jnp.int32), vals)
    return st_, vals


def check_freelist(state, cfg=CFG):
    """5 + 6: the carried allocator state never drifts from slot_owner."""
    owner = np.asarray(state["slot_owner"])
    fq = np.asarray(state["free_q"])
    fh = np.asarray(state["free_head"])
    fc = np.asarray(state["free_count"])
    for r in (ot.NEW, ot.HOT, ot.COLD):
        lo, hi = cfg.region(r)
        cap = hi - lo
        free_slots = set(lo + np.nonzero(owner[lo:hi] == -1)[0])
        ring = list(fq[lo + (fh[r] + np.arange(fc[r])) % cap])
        assert fc[r] == len(free_slots), \
            f"region {r}: count {fc[r]} != {len(free_slots)} free slots"
        assert len(ring) == len(set(ring)), f"region {r}: ring duplicate"
        assert set(ring) == free_slots, f"region {r}: ring != free slots"
    occ = np.asarray(pl.recompute_sb_occupancy(cfg, state["slot_owner"]))
    assert np.array_equal(np.asarray(state["sb_occ"]), occ), \
        "carried sb_occ drifted from the slot-owner oracle"
    # carried per-slot referenced bits mirror the table access bits
    tbl = np.asarray(state["table"])
    want_ref = np.zeros((cfg.n_slots,), bool)
    for s in range(cfg.n_slots):
        o = owner[s]
        if o >= 0:
            want_ref[s] = bool((tbl[o] >> ot.ACCESS_SHIFT) & 1)
    assert np.array_equal(np.asarray(state["slot_ref"]), want_ref), \
        "carried slot_ref drifted from the table access bits"


def check_invariants(state):
    tbl = np.asarray(state["table"])
    owner = np.asarray(state["slot_owner"])
    live = np.nonzero((tbl >> ot.HEAP_SHIFT) & 0b11 != ot.FREE)[0]
    live = [i for i in range(len(tbl))
            if int(ot.heap_of(state["table"][i])) != ot.FREE]
    slots = [int(ot.slot_of(state["table"][i])) for i in live]
    # 1. slot uniqueness
    assert len(slots) == len(set(slots)), "slot collision"
    for i, s in zip(live, slots):
        # owner inverse mapping coherent
        assert owner[s] == i, f"owner[{s}]={owner[s]} != {i}"
        # 4. heap coherence: heap field matches slot's region
        heap = int(ot.heap_of(state["table"][i]))
        lo, hi = CFG.region(heap)
        assert lo <= s < hi, f"obj {i} heap {heap} slot {s} not in region"
    # owner table has no stale entries
    for s in range(CFG.n_slots):
        if owner[s] >= 0:
            assert int(ot.slot_of(state["table"][owner[s]])) == s
    # 5 + 6. carried free rings + occupancy counters
    check_freelist(state)


def _content_preserved_any_interleaving(windows, arm_last):
    """Property: after arbitrary access patterns + collector passes (with
    and without armed windows), every object reads back its value."""
    state, vals = fresh_pool(32)
    for w, ids in enumerate(windows):
        if arm_last and w == len(windows) - 1:
            state = col.arm(state)
        got, state = pl.read(CFG, state, jnp.asarray(ids, jnp.int32))
        want = np.asarray(vals)[np.asarray(ids)]
        assert np.allclose(np.asarray(got), want), "read-through mismatch"
        state, _ = col.collect(CFG, CCFG, state)
        check_invariants(state)
    got, state = pl.read(CFG, state, jnp.arange(32, dtype=jnp.int32))
    assert np.allclose(np.asarray(got), np.asarray(vals))


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.lists(st.integers(0, 31), min_size=1, max_size=10),
                    min_size=1, max_size=8),
           st.booleans())
    def test_content_preserved_any_interleaving(windows, arm_last):
        _content_preserved_any_interleaving(windows, arm_last)
else:
    def test_content_preserved_any_interleaving():
        """Fallback example when hypothesis is unavailable: a fixed
        interleaving still exercises the property + invariant checks."""
        _content_preserved_any_interleaving(
            [[0, 1, 2, 2, 31], [5], [0, 7, 7, 30, 31, 3], [4]],
            arm_last=True)


def test_epoch_safety_atc_blocks_moves():
    """3: an object accessed during an ARMED window (ATC > 0) must not
    migrate in that window's collect."""
    state, _ = fresh_pool(16)
    # make object 0 hot-eligible: access while armed
    state = col.arm(state)
    _, state = pl.read(CFG, state, jnp.asarray([0], jnp.int32))
    before = int(ot.slot_of(state["table"][0]))
    state, report = col.collect(CFG, CCFG, state)
    after = int(ot.slot_of(state["table"][0]))
    assert before == after, "ATC>0 object moved"
    assert int(report["skipped_atc"]) >= 1
    # unarmed access the next window -> it may move now
    _, state = pl.read(CFG, state, jnp.asarray([0], jnp.int32))
    state, _ = col.collect(CFG, CCFG, state)
    assert int(ot.heap_of(state["table"][0])) == ot.HOT


def test_classification_state_machine():
    """Fig. 5: NEW -accessed-> HOT; idle CIW>C_t -> COLD; COLD -access->
    HOT (a promotion)."""
    state, _ = fresh_pool(8)
    # access 0..3 repeatedly; leave 4..7 idle
    for _ in range(8):
        _, state = pl.read(CFG, state, jnp.arange(4, dtype=jnp.int32))
        state, _ = col.collect(CFG, CCFG, state)
    heaps = [int(ot.heap_of(state["table"][i])) for i in range(8)]
    assert all(h == ot.HOT for h in heaps[:4])
    assert all(h == ot.COLD for h in heaps[4:])
    # touch a cold object -> promoted next collect
    _, state = pl.read(CFG, state, jnp.asarray([6], jnp.int32))
    state, rep = col.collect(CFG, CCFG, state)
    assert int(ot.heap_of(state["table"][6])) == ot.HOT


def test_free_and_realloc():
    state, vals = fresh_pool(16)
    state = pl.free(CFG, state, jnp.asarray([3, 5], jnp.int32))
    assert int(ot.heap_of(state["table"][3])) == ot.FREE
    check_invariants(state)
    nv = jnp.full((2, 4), 9.0, jnp.float32)
    state = pl.alloc(CFG, state, jnp.asarray([3, 40], jnp.int32), nv)
    got, state = pl.read(CFG, state, jnp.asarray([3, 40], jnp.int32))
    assert np.allclose(np.asarray(got), 9.0)
    check_invariants(state)


def test_alloc_spills_when_new_full():
    """Allocation never fails while the pool has space (NEW->COLD->HOT)."""
    state = pl.init(CFG)
    n = CFG.n_slots  # more than NEW region
    k = min(n, CFG.max_objects)
    vals = jnp.ones((k, 4), jnp.float32)
    state = pl.alloc(CFG, state, jnp.arange(k, dtype=jnp.int32), vals)
    live = sum(int(ot.heap_of(state["table"][i])) != ot.FREE
               for i in range(k))
    assert live == k
    check_invariants(state)


def test_fault_accounting_and_tier_conservation():
    """7: demote -> host bytes + rss bytes partition occupied superblocks;
    faulting back restores."""
    state, vals = fresh_pool(32)
    # cool everything into COLD then demote via proactive backend
    for _ in range(6):
        state, rep = col.collect(CFG, CCFG, state)
    stats = pl.superblock_stats(CFG, state)
    becfg = be.BackendConfig(kind="proactive")
    tier, evict = be.step(becfg, CFG, stats, state["sb_tier"],
                          state["sb_evict"], jnp.asarray(True))
    state = dict(state, sb_tier=tier, sb_evict=evict)
    rss0 = float(pl.rss_bytes(CFG, state))
    host0 = float(pl.host_bytes(CFG, state))
    assert host0 > 0, "nothing was demoted"
    # read a demoted object: fault + promote back; content intact
    got, state = pl.read(CFG, state, jnp.asarray([7], jnp.int32))
    assert np.allclose(np.asarray(got)[0], np.asarray(vals)[7])
    assert int(state["total_faults"]) >= 1
    assert float(pl.host_bytes(CFG, state)) < host0
    # conservation: every occupied sb is in exactly one tier
    assert float(pl.rss_bytes(CFG, state)) + \
        float(pl.host_bytes(CFG, state)) >= rss0 + host0 - CFG.sb_bytes


def test_compact_heap_preserves_content():
    state, vals = fresh_pool(24)
    # fragment the NEW region
    state = pl.free(CFG, state, jnp.asarray([1, 3, 5, 7, 9], jnp.int32))
    state = col.compact_heap(CFG, state, ot.NEW)
    check_invariants(state)
    keep = [i for i in range(24) if i not in (1, 3, 5, 7, 9)]
    got, state = pl.read(CFG, state, jnp.asarray(keep, jnp.int32))
    assert np.allclose(np.asarray(got), np.asarray(vals)[keep])
    # dense: live slots of NEW form a prefix
    lo, hi = CFG.region(ot.NEW)
    owner = np.asarray(state["slot_owner"][lo:hi])
    nz = np.nonzero(owner >= 0)[0]
    assert len(nz) == 0 or nz.max() == len(nz) - 1


def _cooked_pool():
    """A pool whose objects have migrated: 0..11 HOT (kept accessed),
    12..31 COLD (idle), with several collect windows behind it."""
    state, vals = fresh_pool(32)
    for _ in range(6):
        _, state = pl.read(CFG, state, jnp.arange(12, dtype=jnp.int32))
        state, _ = col.collect(CFG, CCFG, state)
    heaps = np.asarray(ot.heap_of(state["table"][:32]))
    assert (heaps[:12] == ot.HOT).all() and (heaps[12:] == ot.COLD).all()
    return state, vals


@pytest.mark.parametrize("heap", [ot.HOT, ot.COLD])
def test_compact_heap_interleaved_holes(heap):
    """Compaction on a migrated HOT/COLD region with interleaved holes:
    content survives, live slots form a dense prefix, and the free rings
    + occupancy counters are restocked to match the compacted layout."""
    state, vals = _cooked_pool()
    region_objs = list(range(12)) if heap == ot.HOT else list(range(12, 32))
    holes = region_objs[1::2]                  # every other object
    state = pl.free(CFG, state, jnp.asarray(holes, jnp.int32))
    check_invariants(state)

    state = col.compact_heap(CFG, state, heap)
    check_invariants(state)
    lo, hi = CFG.region(heap)
    owner = np.asarray(state["slot_owner"][lo:hi])
    nz = np.nonzero(owner >= 0)[0]
    assert len(nz) > 0 and nz.max() == len(nz) - 1, "region not dense"
    keep = [i for i in region_objs if i not in holes]
    got, state = pl.read(CFG, state, jnp.asarray(keep, jnp.int32))
    assert np.allclose(np.asarray(got), np.asarray(vals)[keep])
    # compaction restocked the ring: the next alloc reuses the freed
    # region's dense-first holes (via NEW first, which still has space)
    state = pl.alloc(CFG, state, jnp.asarray(holes, jnp.int32),
                     jnp.full((len(holes), 4), 5.0, jnp.float32))
    check_invariants(state)


def test_alloc_spill_new_cold_hot_under_freelist():
    """Alloc spill order under the carried rings: NEW fills first, then
    COLD, then HOT; every op boundary keeps the rings consistent. Uses a
    geometry with more ids than slots so every slot is reachable."""
    cfg = pl.make_config(max_objects=96, slot_words=4, sb_slots=8,
                         page_slots=4, slack=1.0)
    state = pl.init(cfg)
    new_lo, new_hi = cfg.region(ot.NEW)
    cold_lo, cold_hi = cfg.region(ot.COLD)
    n_new, n_cold = new_hi - new_lo, cold_hi - cold_lo
    assert n_new + n_cold + 3 < cfg.max_objects  # ids stay in range

    def fill(state, ids):
        vals = jnp.ones((len(ids), 4), jnp.float32) * jnp.asarray(
            ids, jnp.float32)[:, None]
        return pl.alloc(cfg, state, jnp.asarray(ids, jnp.int32), vals)

    # exactly fill NEW
    state = fill(state, list(range(n_new)))
    check_freelist(state, cfg)
    assert int(state["free_count"][ot.NEW]) == 0
    heaps = [int(ot.heap_of(state["table"][i])) for i in range(n_new)]
    assert all(h == ot.NEW for h in heaps)

    # next batch spills into COLD (not HOT)
    state = fill(state, list(range(n_new, n_new + 4)))
    check_freelist(state, cfg)
    for i in range(n_new, n_new + 4):
        assert int(ot.heap_of(state["table"][i])) == ot.COLD

    # exhaust COLD; the batch STRADDLES the COLD->HOT boundary
    n_left_cold = n_cold - 4
    ids = list(range(n_new + 4, n_new + 4 + n_left_cold + 3))
    state = fill(state, ids)
    check_freelist(state, cfg)
    assert int(state["free_count"][ot.COLD]) == 0
    heaps = [int(ot.heap_of(state["table"][i])) for i in ids]
    assert all(h == ot.COLD for h in heaps[:n_left_cold])
    assert all(h == ot.HOT for h in heaps[n_left_cold:])

    # freed NEW slots go back on the NEW ring and are reused before HOT
    state = pl.free(cfg, state, jnp.asarray([0, 1], jnp.int32))
    check_freelist(state, cfg)
    assert int(state["free_count"][ot.NEW]) == 2
    state = fill(state, [90, 91])
    check_freelist(state, cfg)
    assert int(ot.heap_of(state["table"][90])) == ot.NEW
    assert int(ot.heap_of(state["table"][91])) == ot.NEW


def test_alloc_free_duplicates_in_batch():
    """Duplicated ids in one batch: alloc claims ONE slot (first value
    wins), free releases once — the rings never double-pop/push."""
    state = pl.init(CFG)
    vals = jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 2.0),
                      jnp.full((4,), 3.0)]).astype(jnp.float32)
    state = pl.alloc(CFG, state, jnp.asarray([5, 5, 6], jnp.int32), vals)
    check_invariants(state)
    got, state = pl.read(CFG, state, jnp.asarray([5, 6], jnp.int32))
    assert np.allclose(np.asarray(got)[0], 1.0)   # first occurrence won
    assert np.allclose(np.asarray(got)[1], 3.0)
    state = pl.free(CFG, state, jnp.asarray([5, 5, -1, 6], jnp.int32))
    check_invariants(state)
    assert int(ot.heap_of(state["table"][5])) == ot.FREE


def test_pool_exhaustion_drops_not_corrupts():
    """More live objects requested than slots: the overflowing allocs
    fail cleanly (no slot claimed, no ring corruption) and succeed after
    space is freed."""
    small = pl.make_config(max_objects=64, slot_words=4, sb_slots=8,
                           page_slots=4, slack=0.5)   # 32 slots, 64 ids
    state = pl.init(small)
    vals = jnp.ones((48, 4), jnp.float32)
    state = pl.alloc(small, state, jnp.arange(48, dtype=jnp.int32), vals)
    check_freelist(state, small)
    live = [i for i in range(48)
            if int(ot.heap_of(state["table"][i])) != ot.FREE]
    assert len(live) == small.n_slots          # exactly pool capacity
    assert int(np.asarray(state["free_count"]).sum()) == 0
    state = pl.free(small, state, jnp.asarray(live[:4], jnp.int32))
    state = pl.alloc(small, state, jnp.asarray([60, 61, 62, 63], jnp.int32),
                     jnp.ones((4, 4), jnp.float32))
    check_freelist(state, small)
    for i in (60, 61, 62, 63):
        assert int(ot.heap_of(state["table"][i])) != ot.FREE
