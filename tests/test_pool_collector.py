"""HadesPool + Object Collector invariants (DESIGN.md §5), property-based.

1. slot uniqueness — no two live objects share a slot
2. content preservation — read-through value identical under any
   interleaving of collector passes
3. epoch safety — objects with ATC > 0 are never moved
4. heap coherence — table heap field matches the region of its slot
7. accounting conservation — a superblock is in exactly one tier
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import backend as be
from repro.core import collector as col
from repro.core import object_table as ot
from repro.core import pool as pl

CFG = pl.make_config(max_objects=64, slot_words=4, sb_slots=8,
                     page_slots=4, slack=2.0)
CCFG = col.CollectorConfig()


def fresh_pool(n_alloc=32):
    st_ = pl.init(CFG)
    vals = jnp.arange(n_alloc * 4, dtype=jnp.float32).reshape(n_alloc, 4)
    st_ = pl.alloc(CFG, st_, jnp.arange(n_alloc, dtype=jnp.int32), vals)
    return st_, vals


def check_invariants(state):
    tbl = np.asarray(state["table"])
    owner = np.asarray(state["slot_owner"])
    live = np.nonzero((tbl >> ot.HEAP_SHIFT) & 0b11 != ot.FREE)[0]
    live = [i for i in range(len(tbl))
            if int(ot.heap_of(state["table"][i])) != ot.FREE]
    slots = [int(ot.slot_of(state["table"][i])) for i in live]
    # 1. slot uniqueness
    assert len(slots) == len(set(slots)), "slot collision"
    for i, s in zip(live, slots):
        # owner inverse mapping coherent
        assert owner[s] == i, f"owner[{s}]={owner[s]} != {i}"
        # 4. heap coherence: heap field matches slot's region
        heap = int(ot.heap_of(state["table"][i]))
        lo, hi = CFG.region(heap)
        assert lo <= s < hi, f"obj {i} heap {heap} slot {s} not in region"
    # owner table has no stale entries
    for s in range(CFG.n_slots):
        if owner[s] >= 0:
            assert int(ot.slot_of(state["table"][owner[s]])) == s


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(0, 31), min_size=1, max_size=10),
                min_size=1, max_size=8),
       st.booleans())
def test_content_preserved_any_interleaving(windows, arm_last):
    """Property: after arbitrary access patterns + collector passes (with
    and without armed windows), every object reads back its value."""
    state, vals = fresh_pool(32)
    for w, ids in enumerate(windows):
        if arm_last and w == len(windows) - 1:
            state = col.arm(state)
        got, state = pl.read(CFG, state, jnp.asarray(ids, jnp.int32))
        want = np.asarray(vals)[np.asarray(ids)]
        assert np.allclose(np.asarray(got), want), "read-through mismatch"
        state, _ = col.collect(CFG, CCFG, state)
        check_invariants(state)
    got, state = pl.read(CFG, state, jnp.arange(32, dtype=jnp.int32))
    assert np.allclose(np.asarray(got), np.asarray(vals))


def test_epoch_safety_atc_blocks_moves():
    """3: an object accessed during an ARMED window (ATC > 0) must not
    migrate in that window's collect."""
    state, _ = fresh_pool(16)
    # make object 0 hot-eligible: access while armed
    state = col.arm(state)
    _, state = pl.read(CFG, state, jnp.asarray([0], jnp.int32))
    before = int(ot.slot_of(state["table"][0]))
    state, report = col.collect(CFG, CCFG, state)
    after = int(ot.slot_of(state["table"][0]))
    assert before == after, "ATC>0 object moved"
    assert int(report["skipped_atc"]) >= 1
    # unarmed access the next window -> it may move now
    _, state = pl.read(CFG, state, jnp.asarray([0], jnp.int32))
    state, _ = col.collect(CFG, CCFG, state)
    assert int(ot.heap_of(state["table"][0])) == ot.HOT


def test_classification_state_machine():
    """Fig. 5: NEW -accessed-> HOT; idle CIW>C_t -> COLD; COLD -access->
    HOT (a promotion)."""
    state, _ = fresh_pool(8)
    # access 0..3 repeatedly; leave 4..7 idle
    for _ in range(8):
        _, state = pl.read(CFG, state, jnp.arange(4, dtype=jnp.int32))
        state, _ = col.collect(CFG, CCFG, state)
    heaps = [int(ot.heap_of(state["table"][i])) for i in range(8)]
    assert all(h == ot.HOT for h in heaps[:4])
    assert all(h == ot.COLD for h in heaps[4:])
    # touch a cold object -> promoted next collect
    _, state = pl.read(CFG, state, jnp.asarray([6], jnp.int32))
    state, rep = col.collect(CFG, CCFG, state)
    assert int(ot.heap_of(state["table"][6])) == ot.HOT


def test_free_and_realloc():
    state, vals = fresh_pool(16)
    state = pl.free(CFG, state, jnp.asarray([3, 5], jnp.int32))
    assert int(ot.heap_of(state["table"][3])) == ot.FREE
    check_invariants(state)
    nv = jnp.full((2, 4), 9.0, jnp.float32)
    state = pl.alloc(CFG, state, jnp.asarray([3, 40], jnp.int32), nv)
    got, state = pl.read(CFG, state, jnp.asarray([3, 40], jnp.int32))
    assert np.allclose(np.asarray(got), 9.0)
    check_invariants(state)


def test_alloc_spills_when_new_full():
    """Allocation never fails while the pool has space (NEW->COLD->HOT)."""
    state = pl.init(CFG)
    n = CFG.n_slots  # more than NEW region
    k = min(n, CFG.max_objects)
    vals = jnp.ones((k, 4), jnp.float32)
    state = pl.alloc(CFG, state, jnp.arange(k, dtype=jnp.int32), vals)
    live = sum(int(ot.heap_of(state["table"][i])) != ot.FREE
               for i in range(k))
    assert live == k
    check_invariants(state)


def test_fault_accounting_and_tier_conservation():
    """7: demote -> host bytes + rss bytes partition occupied superblocks;
    faulting back restores."""
    state, vals = fresh_pool(32)
    # cool everything into COLD then demote via proactive backend
    for _ in range(6):
        state, rep = col.collect(CFG, CCFG, state)
    stats = pl.superblock_stats(CFG, state)
    becfg = be.BackendConfig(kind="proactive")
    tier, evict = be.step(becfg, CFG, stats, state["sb_tier"],
                          state["sb_evict"], jnp.asarray(True))
    state = dict(state, sb_tier=tier, sb_evict=evict)
    rss0 = float(pl.rss_bytes(CFG, state))
    host0 = float(pl.host_bytes(CFG, state))
    assert host0 > 0, "nothing was demoted"
    # read a demoted object: fault + promote back; content intact
    got, state = pl.read(CFG, state, jnp.asarray([7], jnp.int32))
    assert np.allclose(np.asarray(got)[0], np.asarray(vals)[7])
    assert int(state["total_faults"]) >= 1
    assert float(pl.host_bytes(CFG, state)) < host0
    # conservation: every occupied sb is in exactly one tier
    assert float(pl.rss_bytes(CFG, state)) + \
        float(pl.host_bytes(CFG, state)) >= rss0 + host0 - CFG.sb_bytes


def test_compact_heap_preserves_content():
    state, vals = fresh_pool(24)
    # fragment the NEW region
    state = pl.free(CFG, state, jnp.asarray([1, 3, 5, 7, 9], jnp.int32))
    state = col.compact_heap(CFG, state, ot.NEW)
    check_invariants(state)
    keep = [i for i in range(24) if i not in (1, 3, 5, 7, 9)]
    got, state = pl.read(CFG, state, jnp.asarray(keep, jnp.int32))
    assert np.allclose(np.asarray(got), np.asarray(vals)[keep])
    # dense: live slots of NEW form a prefix
    lo, hi = CFG.region(ot.NEW)
    owner = np.asarray(state["slot_owner"][lo:hi])
    nz = np.nonzero(owner >= 0)[0]
    assert len(nz) == 0 or nz.max() == len(nz) - 1
