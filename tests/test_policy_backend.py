"""MIAD policy (invariant 5) + backend behaviour/obliviousness."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import backend as be
from repro.core import object_table as ot
from repro.core import policy
from repro.core import pool as pl

MCFG = policy.MiadConfig()


@settings(max_examples=100, deadline=None)
@given(st.floats(1.0, 16.0), st.integers(0, 10),
       st.integers(0, 1000), st.integers(1, 1000))
def test_miad_bounds_and_monotonicity(ct, calm, promos, accesses):
    promos = min(promos, accesses)
    new_ct, new_calm, rate, ok = policy.update(
        MCFG, jnp.asarray(ct, jnp.float32), jnp.asarray(calm),
        jnp.asarray(promos), jnp.asarray(accesses))
    # bounds
    assert MCFG.c_min - 1e-6 <= float(new_ct) <= MCFG.c_max + 1e-6
    if rate > MCFG.target:
        # multiplicative increase (strict unless already at max)
        assert float(new_ct) >= ct or ct >= MCFG.c_max - 1e-6
        assert int(new_calm) == 0
    else:
        assert float(new_ct) <= ct or ct <= MCFG.c_min + 1e-6
        assert int(new_calm) == calm + 1


def _stats(n=8, occ=None, ref=None, region=None):
    occ = jnp.asarray(occ if occ is not None else [4] * n, jnp.int32)
    ref = jnp.asarray(ref if ref is not None else [False] * n)
    region = jnp.asarray(region if region is not None
                         else [ot.COLD] * n, jnp.int8)
    return {"occupancy": occ, "referenced": ref, "region": region,
            "tier": jnp.zeros(n, jnp.int8),
            "evict": jnp.zeros(n, jnp.int8)}


PCFG = pl.make_config(max_objects=64, slot_words=4, sb_slots=8, slack=1.0)


def test_backend_interface_is_object_oblivious():
    """The backend signature admits ONLY superblock-level inputs — this
    is the architectural decoupling, checked at the API boundary."""
    import inspect
    sig = inspect.signature(be.step)
    assert set(sig.parameters) == {"cfg", "pool_cfg", "stats", "tier",
                                   "evict", "proactive_ok"}


def test_reactive_prefers_unreferenced():
    n = PCFG.n_sbs
    ref = [i % 2 == 0 for i in range(n)]         # even sbs referenced
    stats = _stats(n, ref=ref)
    cfg = be.BackendConfig(kind="reactive",
                           hbm_target_bytes=(n // 2) * PCFG.sb_bytes)
    tier, evict = be.step(cfg, PCFG, stats, stats["tier"], stats["evict"],
                          jnp.asarray(False))
    demoted = np.asarray(tier) == pl.HOST
    # all demoted sbs are unreferenced ones
    assert demoted.sum() == n // 2
    assert not any(demoted[i] and ref[i] for i in range(n))


def test_cap_backend_is_hotness_blind():
    n = PCFG.n_sbs
    ref = [True] * n                              # everything referenced
    stats = _stats(n, ref=ref)
    cfg = be.BackendConfig(kind="cap",
                           hbm_target_bytes=2 * PCFG.sb_bytes)
    tier, _ = be.step(cfg, PCFG, stats, stats["tier"], stats["evict"],
                      jnp.asarray(False))
    # cap evicts regardless of referenced bits
    assert (np.asarray(tier) == pl.HOST).sum() == n - 2


def test_proactive_gated_by_miad():
    n = PCFG.n_sbs
    stats = _stats(n)
    evict0 = jnp.full((n,), pl.CANDIDATE, jnp.int8)
    cfg = be.BackendConfig(kind="proactive")
    tier, evict = be.step(cfg, PCFG, stats, stats["tier"], evict0,
                          jnp.asarray(False))
    assert (np.asarray(tier) == pl.HOST).sum() == 0   # gate closed
    tier, evict = be.step(cfg, PCFG, stats, stats["tier"], evict0,
                          jnp.asarray(True))
    assert (np.asarray(tier) == pl.HOST).sum() == n   # gate open


def test_null_backend_never_reclaims():
    stats = _stats(PCFG.n_sbs)
    cfg = be.BackendConfig(kind="null")
    tier, evict = be.step(cfg, PCFG, stats, stats["tier"],
                          jnp.full((PCFG.n_sbs,), pl.CANDIDATE, jnp.int8),
                          jnp.asarray(True))
    assert (np.asarray(tier) == pl.HBM).all()
