"""MIAD policy (invariant 5) + the pluggable backend protocol:
construction-time validation, object-obliviousness at the API boundary,
behaviour of all six registered backends (incl. the stateful mglru /
promote), and the deprecated shims."""
import dataclasses
import inspect

import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dep (requirements-dev.txt); only the MIAD property
    # test needs it — the backend-protocol tests always run
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    def settings(*a, **k):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in namespace
        floats = integers = staticmethod(lambda *a, **k: None)

from repro.core import backend as be
from repro.core import object_table as ot
from repro.core import policy
from repro.core import pool as pl

MCFG = policy.MiadConfig()


@settings(max_examples=100, deadline=None)
@given(st.floats(1.0, 16.0), st.integers(0, 10),
       st.integers(0, 1000), st.integers(1, 1000))
def test_miad_bounds_and_monotonicity(ct, calm, promos, accesses):
    promos = min(promos, accesses)
    new_ct, new_calm, rate, ok = policy.update(
        MCFG, jnp.asarray(ct, jnp.float32), jnp.asarray(calm),
        jnp.asarray(promos), jnp.asarray(accesses))
    # bounds
    assert MCFG.c_min - 1e-6 <= float(new_ct) <= MCFG.c_max + 1e-6
    if rate > MCFG.target:
        # multiplicative increase (strict unless already at max)
        assert float(new_ct) >= ct or ct >= MCFG.c_max - 1e-6
        assert int(new_calm) == 0
    else:
        assert float(new_ct) <= ct or ct <= MCFG.c_min + 1e-6
        assert int(new_calm) == calm + 1


def _stats(n=8, occ=None, ref=None, region=None, tier=None, evict=None):
    occ = jnp.asarray(occ if occ is not None else [4] * n, jnp.int32)
    ref = jnp.asarray(ref if ref is not None else [False] * n)
    region = jnp.asarray(region if region is not None
                         else [ot.COLD] * n, jnp.int8)
    tier = jnp.asarray(tier if tier is not None else [pl.HBM] * n,
                       jnp.int8)
    evict = jnp.asarray(evict if evict is not None else [pl.NORMAL] * n,
                        jnp.int8)
    return {"occupancy": occ, "referenced": ref, "region": region,
            "tier": tier, "evict": evict}


PCFG = pl.make_config(max_objects=64, slot_words=4, sb_slots=8, slack=1.0)


def _step(backend, stats, *, bstate=None, ok=False):
    """One protocol step against the stats' own tier/evict columns."""
    bstate = backend.init(PCFG) if bstate is None else bstate
    return backend.step(
        PCFG, bstate, stats, stats["tier"], stats["evict"],
        {"proactive_ok": jnp.asarray(ok), "epoch": jnp.asarray(0)})


# ---------------------------------------------------------------------------
# registry / construction-time validation
# ---------------------------------------------------------------------------
def test_registry_names_and_unknown_rejected_at_construction():
    assert set(be.names()) >= {"reactive", "proactive", "cap", "null",
                               "mglru", "promote"}
    with pytest.raises(ValueError, match="reactve"):
        be.make("reactve")                     # the motivating typo
    with pytest.raises(ValueError, match="registered"):
        be.BackendConfig(kind="reactve")       # shim validates too
    with pytest.raises(TypeError):
        be.make("null", hbm_target_bytes=1)    # unknown param


def test_backend_interface_is_object_oblivious():
    """The protocol signature admits ONLY page-level inputs (geometry,
    carried state, superblock stats, tier/evict columns, frontend
    signals) — the architectural decoupling, checked at the API
    boundary. No object table, no pool state."""
    sig = inspect.signature(be.Backend.step)
    assert set(sig.parameters) == {"self", "geom", "bstate", "stats",
                                   "tier", "evict", "signals"}
    for name in be.names():
        cls = type(be.make(name))
        assert set(inspect.signature(cls.step).parameters) == \
            set(sig.parameters), name
        # hyperparameters are static scalars, never arrays
        for f in dataclasses.fields(cls):
            assert f.type in ("int", "bool", "float", "str"), \
                f"{name}.{f.name} must be a static hyperparameter"


def test_telemetry_structure_is_fixed():
    """Every backend emits the same telemetry pytree (lax.cond branches
    and backend swaps keep one report structure)."""
    stats = _stats(PCFG.n_sbs)
    want = set(be.TELEMETRY_KEYS)
    for name in be.names():
        b = be.make(name)
        _, _, _, telem = _step(b, stats)
        assert set(telem) == want, name


# ---------------------------------------------------------------------------
# the four ported backends
# ---------------------------------------------------------------------------
def test_reactive_prefers_unreferenced():
    n = PCFG.n_sbs
    ref = [i % 2 == 0 for i in range(n)]         # even sbs referenced
    stats = _stats(n, ref=ref)
    b = be.make("reactive", hbm_target_bytes=(n // 2) * PCFG.sb_bytes)
    _, tier, evict, telem = _step(b, stats)
    demoted = np.asarray(tier) == pl.HOST
    # all demoted sbs are unreferenced ones
    assert demoted.sum() == n // 2
    assert not any(demoted[i] and ref[i] for i in range(n))
    assert int(telem["be_demoted"]) == n // 2


def test_reactive_strict_mode_never_evicts_referenced():
    """evict_referenced=False (the simulator's kswapd): the referenced
    set is a hard memory ceiling even under unbounded pressure."""
    n = PCFG.n_sbs
    stats = _stats(n, ref=[True] * n)
    strict = be.make("reactive", hbm_target_bytes=0,
                     evict_referenced=False)
    _, tier, _, _ = _step(strict, stats)
    assert (np.asarray(tier) == pl.HBM).all()
    # while the framework default escalates into the active list
    loose = be.make("reactive", hbm_target_bytes=0)
    _, tier, _, _ = _step(loose, stats)
    assert (np.asarray(tier) == pl.HOST).all()


def test_cap_backend_is_hotness_blind():
    n = PCFG.n_sbs
    stats = _stats(n, ref=[True] * n)             # everything referenced
    b = be.make("cap", hbm_target_bytes=2 * PCFG.sb_bytes)
    _, tier, _, _ = _step(b, stats)
    # cap evicts regardless of referenced bits
    assert (np.asarray(tier) == pl.HOST).sum() == n - 2


def test_proactive_gated_by_miad():
    n = PCFG.n_sbs
    stats = _stats(n, evict=[pl.CANDIDATE] * n)
    b = be.make("proactive")
    _, tier, evict, _ = _step(b, stats, ok=False)
    assert (np.asarray(tier) == pl.HOST).sum() == 0   # gate closed
    _, tier, evict, _ = _step(b, stats, ok=True)
    assert (np.asarray(tier) == pl.HOST).sum() == n   # gate open


def test_null_backend_never_reclaims():
    stats = _stats(PCFG.n_sbs, evict=[pl.CANDIDATE] * PCFG.n_sbs)
    _, tier, _, _ = _step(be.make("null"), stats, ok=True)
    assert (np.asarray(tier) == pl.HBM).all()


# ---------------------------------------------------------------------------
# the stateful backends
# ---------------------------------------------------------------------------
def test_mglru_ages_idle_and_demotes_oldest_first():
    n = PCFG.n_sbs
    b = be.make("mglru", hbm_target_bytes=n * PCFG.sb_bytes)  # no pressure
    ref = [i < n // 2 for i in range(n)]          # first half stays hot
    stats = _stats(n, ref=ref)
    bstate = b.init(PCFG)
    for w in range(5):
        bstate, tier, evict, _ = _step(b, stats, bstate=bstate)
    gen = np.asarray(bstate["gen"])
    assert (gen[:n // 2] == 0).all()              # referenced: youngest
    assert (gen[n // 2:] == b.max_gen).all()      # idle: saturated old
    assert (np.asarray(tier) == pl.HBM).all()     # no pressure, no demote

    # now apply pressure for half the pool: victims come from the oldest
    # generation; the referenced (gen-0) working set is protected
    pressured = be.make("mglru",
                        hbm_target_bytes=(n // 2) * PCFG.sb_bytes)
    bstate2, tier, evict, telem = _step(pressured, stats, bstate=bstate)
    demoted = np.asarray(tier) == pl.HOST
    assert demoted.sum() == n // 2
    assert not demoted[:n // 2].any()
    assert int(telem["be_demoted"]) == n // 2


def test_mglru_protects_young_generations():
    """min_evict_gen: superblocks referenced within the last window are
    never demoted even when pressure exceeds the aged population — and
    min_evict_gen=0 genuinely disables the protection."""
    n = PCFG.n_sbs
    b = be.make("mglru", hbm_target_bytes=0)      # unbounded pressure
    stats = _stats(n, ref=[True] * n)             # everything referenced
    _, tier, _, _ = _step(b, stats)
    assert (np.asarray(tier) == pl.HBM).all()
    unprotected = be.make("mglru", hbm_target_bytes=0, min_evict_gen=0)
    _, tier, _, _ = _step(unprotected, stats)
    assert (np.asarray(tier) == pl.HOST).all()


def test_promote_watermark_hysteresis():
    n = PCFG.n_sbs
    sb = PCFG.sb_bytes
    b = be.make("promote", hbm_high_bytes=(n // 2) * sb,
                hbm_low_bytes=(n // 4) * sb, promote_after=2)
    # phase 1: residency AT the high watermark, hot data stuck on HOST
    tier = [pl.HBM] * (n // 2) + [pl.HOST] * (n - n // 2)
    stats = _stats(n, ref=[True] * n, tier=tier,
                   evict=[pl.NORMAL] * n)
    bstate = b.init(PCFG)
    for w in range(3):
        bstate, out_tier, _, telem = _step(b, stats, bstate=bstate)
        # at/above high: promotion is off no matter how hot HOST data is
        assert int(telem["be_promoted"]) == 0
        assert not bool(bstate["active"])
    assert (np.asarray(bstate["host_refs"])[n // 2:] >= 2).all()

    # phase 2: residency falls below the LOW watermark -> hysteresis
    # re-arms and hot HOST superblocks re-tier (streaks >= promote_after
    # were carried across windows), never past the high watermark
    tier2 = [pl.HBM] * (n // 8) + [pl.HOST] * (n - n // 8)
    stats2 = _stats(n, ref=[True] * n, tier=tier2)
    bstate, out_tier, out_evict, telem = _step(b, stats2, bstate=bstate)
    promoted = int(telem["be_promoted"])
    assert promoted > 0
    n_res = int((np.asarray(out_tier) == pl.HBM).sum())
    assert n_res <= n // 2                        # never past high
    # promotion filled residency to the high watermark -> the latch is
    # OFF again until the next low dip (anti-ping-pong)
    assert n_res == n // 2 and not bool(bstate["active"])


def test_promote_requires_consecutive_referenced_windows():
    """promote_after=2: one referenced window is not enough, and an idle
    window resets the streak."""
    n = PCFG.n_sbs
    b = be.make("promote", promote_after=2)
    hot = _stats(n, ref=[True] * n, tier=[pl.HOST] * n)
    cold = _stats(n, ref=[False] * n, tier=[pl.HOST] * n)
    bstate = b.init(PCFG)
    bstate, tier, _, telem = _step(b, hot, bstate=bstate)
    assert int(telem["be_promoted"]) == 0         # streak = 1
    bstate, tier, _, telem = _step(b, cold, bstate=bstate)
    assert int(telem["be_promoted"]) == 0         # streak reset
    assert (np.asarray(bstate["host_refs"]) == 0).all()
    bstate, tier, _, telem = _step(b, hot, bstate=bstate)
    bstate, tier, _, telem = _step(b, hot, bstate=bstate)
    assert int(telem["be_promoted"]) == n         # 2 consecutive windows
    assert (np.asarray(tier) == pl.HBM).all()


def test_promote_demotes_above_high_watermark():
    n = PCFG.n_sbs
    sb = PCFG.sb_bytes
    b = be.make("promote", hbm_high_bytes=(n // 2) * sb)
    ref = [i % 2 == 0 for i in range(n)]
    stats = _stats(n, ref=ref)                    # all resident, over cap
    _, tier, _, telem = _step(b, stats)
    demoted = np.asarray(tier) == pl.HOST
    # low defaults to high: reclaim down to the (collapsed) band
    assert demoted.sum() == n - n // 2
    # kswapd priorities: unreferenced go first
    assert not any(demoted[i] and ref[i] for i in range(n)) or \
        demoted.sum() > (~np.asarray(ref)).sum()

    # with a real band, reclaim goes PAST the trigger point down to LOW
    # (kswapd semantics), leaving promotion headroom
    banded = be.make("promote", hbm_high_bytes=(n // 2) * sb,
                     hbm_low_bytes=(n // 4) * sb)
    _, tier, _, _ = _step(banded, stats)
    assert (np.asarray(tier) == pl.HBM).sum() == n // 4


# ---------------------------------------------------------------------------
# deprecated shims
# ---------------------------------------------------------------------------
def test_legacy_step_shim_and_config_build():
    n = PCFG.n_sbs
    ref = [i % 3 == 0 for i in range(n)]
    stats = _stats(n, ref=ref)
    cfg = be.BackendConfig(kind="reactive",
                           hbm_target_bytes=3 * PCFG.sb_bytes)
    tier_a, evict_a = be.step(cfg, PCFG, stats, stats["tier"],
                              stats["evict"], jnp.asarray(False))
    b = cfg.build()
    assert isinstance(b, be.ReactiveBackend)
    assert b.hbm_target_bytes == 3 * PCFG.sb_bytes
    _, tier_b, evict_b, _ = _step(b, stats)
    assert np.array_equal(np.asarray(tier_a), np.asarray(tier_b))
    assert np.array_equal(np.asarray(evict_a), np.asarray(evict_b))
    # the shim maps the pressure target onto promote's high watermark
    assert be.BackendConfig(
        kind="promote", hbm_target_bytes=128).build().hbm_high_bytes == 128
    # the one shared target->field mapping (launchers + shim + sim)
    assert be.pressure_params("cap", 64) == {"hbm_target_bytes": 64}
    assert be.pressure_params("promote", 64) == {"hbm_high_bytes": 64}
    assert be.pressure_params("null", 64) == {}      # no pressure field
    assert be.pressure_params("mglru", 0) == {}      # no target set
    with pytest.raises(ValueError):
        be.pressure_params("bogus", 64)
