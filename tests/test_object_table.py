"""Object-table word packing: lossless round-trip + field isolation
(the tagged-pointer invariant: metadata updates never corrupt the slot)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import object_table as ot

slots = st.integers(0, (1 << ot.SLOT_BITS) - 1)
heaps = st.integers(0, 3)
bits = st.integers(0, 1)
atcs = st.integers(0, (1 << ot.ATC_BITS) - 1)
ciws = st.integers(0, (1 << ot.CIW_BITS) - 1)


@settings(max_examples=200, deadline=None)
@given(slots, heaps, bits, atcs, ciws)
def test_pack_roundtrip(slot, heap, acc, atc, ciw):
    w = ot.pack(slot, heap, acc, atc, ciw)
    assert int(ot.slot_of(w)) == slot
    assert int(ot.heap_of(w)) == heap
    assert int(ot.access_of(w)) == acc
    assert int(ot.atc_of(w)) == atc
    assert int(ot.ciw_of(w)) == ciw


@settings(max_examples=100, deadline=None)
@given(slots, heaps, bits, atcs, ciws, slots, heaps, atcs, ciws)
def test_field_updates_isolated(slot, heap, acc, atc, ciw,
                                slot2, heap2, atc2, ciw2):
    w = ot.pack(slot, heap, acc, atc, ciw)
    w2 = ot.with_slot(w, slot2)
    assert int(ot.slot_of(w2)) == slot2 and int(ot.heap_of(w2)) == heap
    w3 = ot.with_heap(w, heap2)
    assert int(ot.heap_of(w3)) == heap2 and int(ot.slot_of(w3)) == slot
    w4 = ot.with_atc(w, atc2)
    assert int(ot.atc_of(w4)) == atc2 and int(ot.ciw_of(w4)) == ciw
    w5 = ot.with_ciw(w, ciw2)
    assert int(ot.ciw_of(w5)) == ciw2 and int(ot.access_of(w5)) == acc


def test_record_access_idempotent_and_armed():
    tbl = ot.make_table(8)
    tbl = tbl.at[jnp.arange(4)].set(ot.pack(jnp.arange(4, dtype=jnp.uint32),
                                            ot.NEW))
    ids = jnp.asarray([0, 1, 1, 1, -1], jnp.int32)
    t1 = ot.record_access(tbl, ids, armed=False)
    assert int(ot.access_of(t1[1])) == 1
    assert int(ot.atc_of(t1[1])) == 0            # unarmed: no ATC
    # idempotent: second pass changes nothing
    t2 = ot.record_access(t1, ids, armed=False)
    assert bool(jnp.all(t1 == t2))
    # armed: ATC bumps (saturating), dead/invalid ids untouched
    t3 = ot.record_access(tbl, ids, armed=True)
    assert int(ot.atc_of(t3[1])) >= 1
    assert int(ot.access_of(t3[7])) == 0
    # clear wipes access+atc but keeps slot/heap/ciw
    t4 = ot.clear_access_and_atc(t3)
    assert int(ot.access_of(t4[1])) == 0 and int(ot.atc_of(t4[1])) == 0
    assert int(ot.slot_of(t4[1])) == 1
