"""Fused-window engine parity: `Engine.run_window` (one jitted scan per
window) must produce BIT-identical pool state, read outputs, and collect
reports vs. the step-by-step `Hades` loop — with both the jnp-oracle and
the Pallas (interpret-mode) collector — plus the fused single-pass
migration vs. the kernels' contracts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Hades, HadesOptions, make_config
from repro.core import backend as be
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import object_table as ot
from repro.core import pool as pl
from repro.core.backend import BackendConfig

CFG = make_config(max_objects=64, slot_words=8, sb_slots=8, page_slots=4,
                  slack=2.0)


def _opts(use_pallas=False, overlap=False, backend="proactive", every=4):
    return HadesOptions(
        collect_every=every, backend=BackendConfig(kind=backend),
        collector=col.CollectorConfig(use_pallas=use_pallas),
        overlap_collect=overlap)


def _mixed_steps(rng, n_steps=15, n_objs=48):
    """alloc + a random interleaving of read/write/free/alloc batches."""
    vals = np.arange(n_objs * CFG.slot_words,
                     dtype=np.float32).reshape(n_objs, CFG.slot_words)
    steps = [("alloc", np.arange(n_objs), vals)]
    for t in range(n_steps):
        kind = rng.choice(["read", "read", "read", "write", "free",
                           "alloc"])
        pick = rng.integers(0, n_objs, size=6)
        if kind in ("write", "alloc"):
            steps.append((kind, pick,
                          rng.normal(size=(6, CFG.slot_words)).astype(
                              np.float32)))
        else:
            steps.append((kind, pick, None))
    return steps


def _drive_hades(opts, steps):
    h = Hades(CFG, opts)
    outs = []
    for op, ids, values in steps:
        if op == "read":
            outs.append(np.asarray(h.read(ids)))
        elif op == "write":
            h.write(ids, values)
        elif op == "alloc":
            h.alloc(ids, values)
        elif op == "free":
            h.free(ids)
    return h, outs


def _assert_state_equal(a, b):
    flat_a, _ = jax.tree_util.tree_flatten_with_path(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b), "state structure diverged"
    for (path, x), y in zip(flat_a, flat_b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"state{jax.tree_util.keystr(path)} diverged"


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("overlap", [False, True])
def test_run_window_matches_hades_loop(use_pallas, overlap):
    """One fused dispatch == N per-op dispatches, bit for bit (table,
    heap data, tiers, counters, reports), jnp and Pallas collectors."""
    rng = np.random.default_rng(0)
    steps = _mixed_steps(rng)
    opts = _opts(use_pallas=use_pallas, overlap=overlap)

    h, per_op_reads = _drive_hades(opts, steps)

    e = eng.Engine(CFG, opts)
    trace = eng.make_trace(CFG, steps)
    state, outs, reports = e.run_window(e.init(), trace, 0)

    _assert_state_equal(h.state, state)
    # read outputs: fused trace pads ids to k with -1 -> zeros rows
    outs = np.asarray(outs)
    ridx = [i for i, (op, _, _) in enumerate(steps) if op == "read"]
    for got, i in zip(per_op_reads, ridx):
        assert np.array_equal(got, outs[i, :got.shape[0]])
    # reports at the collect steps match the per-op path's last_report
    reps = eng.window_reports(reports)
    assert len(reps) == len(steps) // opts.collect_every
    for k, v in h.last_report.items():
        assert float(v) == reps[-1][k], k


def test_pallas_and_jnp_collectors_bit_identical():
    """The use_pallas collector (access_scan + migrate kernels, interpret
    mode) is bit-identical to the jnp oracle over a mixed trace."""
    rng = np.random.default_rng(1)
    steps = _mixed_steps(rng, n_steps=20)
    trace = eng.make_trace(CFG, steps)

    e_j = eng.Engine(CFG, _opts(use_pallas=False))
    e_p = eng.Engine(CFG, _opts(use_pallas=True))
    s_j, o_j, r_j = e_j.run_window(e_j.init(), trace, 0)
    s_p, o_p, r_p = e_p.run_window(e_p.init(), trace, 0)
    _assert_state_equal(s_j, s_p)
    assert np.array_equal(np.asarray(o_j), np.asarray(o_p))
    for k in r_j:
        assert np.array_equal(np.asarray(r_j[k]), np.asarray(r_p[k])), k


@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_migration_matches_sequential_passes(use_pallas):
    """The single fused data movement must equal the old two-pass
    sequential migration: hot movers land densely in HOT, cold movers may
    claim slots hot movers vacated, payloads survive."""
    state = pl.init(CFG)
    n = 48
    vals = jnp.arange(n * CFG.slot_words,
                      dtype=jnp.float32).reshape(n, CFG.slot_words)
    state = pl.alloc(CFG, state, jnp.arange(n, dtype=jnp.int32), vals)
    ccfg = col.CollectorConfig(use_pallas=use_pallas)
    # several windows: reads promote a subset hot, the idle rest cools
    for w in range(6):
        _, state = pl.read(CFG, state, jnp.arange(0, 12, dtype=jnp.int32))
        state, rep = col.collect(CFG, ccfg, state)
    # classification outcome
    heaps = np.asarray(ot.heap_of(state["table"][:n]))
    assert (heaps[:12] == ot.HOT).all()
    assert (heaps[12:] == ot.COLD).all()
    # payload integrity after all moves
    got, state = pl.read(CFG, state, jnp.arange(n, dtype=jnp.int32))
    assert np.array_equal(np.asarray(got), np.asarray(vals))
    # HOT landing is dense from the region start
    lo, hi = CFG.region(ot.HOT)
    owner = np.asarray(state["slot_owner"][lo:hi])
    nz = np.nonzero(owner >= 0)[0]
    assert nz.max() == len(nz) - 1


def test_every_one_overlap_aligned_matches_generic():
    """Degenerate cadence (collect_every=1, overlap on): the cond-free
    aligned shape must still agree bit-for-bit with the generic shape
    (arm fires after the op on both)."""
    rng = np.random.default_rng(3)
    steps = _mixed_steps(rng, n_steps=7)
    trace = eng.make_trace(CFG, steps)
    opts = _opts(overlap=True, every=1)
    e = eng.Engine(CFG, opts)
    s_a, o_a, r_a = e.run_window(e.init(), trace, 0)        # aligned
    s_g, o_g, r_g = e.run_window(e.init(), trace,
                                 jnp.int32(0))              # generic
    _assert_state_equal(s_a, s_g)
    assert np.array_equal(np.asarray(o_a), np.asarray(o_g))
    for k in r_a:
        assert np.array_equal(np.asarray(r_a[k]), np.asarray(r_g[k])), k
    h, _ = _drive_hades(opts, steps)
    _assert_state_equal(h.state, s_a)


def test_serve_steps_streams_windows():
    """Chunked streaming (`serve_steps`) equals the one-shot scan and
    surfaces one report per closed window."""
    rng = np.random.default_rng(2)
    steps = _mixed_steps(rng, n_steps=15)
    trace = eng.make_trace(CFG, steps)
    opts = _opts()
    e = eng.Engine(CFG, opts)
    s1, o1, r1 = e.run_window(e.init(), trace, 0)
    s2, o2, reps = e.serve_steps(e.init(), trace)
    _assert_state_equal(s1, s2)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert len(reps) == len(steps) // opts.collect_every
    assert all(r["did_collect"] for r in reps)


def test_free_advances_window_clock():
    """Engine contract: every trace op ticks the clock, including free —
    a window of `collect_every` ops always closes with a collect."""
    opts = _opts(every=4)
    e = eng.Engine(CFG, opts)
    vals = np.ones((8, CFG.slot_words), np.float32)
    steps = [("alloc", np.arange(8), vals), ("read", np.arange(8), None),
             ("free", np.arange(4), None), ("read", np.arange(4, 8), None)]
    _, _, reports = e.run_window(e.init(), eng.make_trace(CFG, steps), 0)
    assert np.asarray(reports["did_collect"]).tolist() == [
        False, False, False, True]


@pytest.mark.parametrize("backend", [
    be.make("mglru", hbm_target_bytes=4 * CFG.sb_bytes),
    be.make("promote", hbm_high_bytes=4 * CFG.sb_bytes,
            hbm_low_bytes=2 * CFG.sb_bytes),
])
def test_stateful_backends_ride_the_scan_carry(backend):
    """The stateful backends run INSIDE the fused window: bstate is
    carried across windows by the scan (one dispatch per run_window
    call), bit-identical to the per-op Hades loop, and actually evolves
    (mglru generations age; promote streaks/hysteresis move)."""
    rng = np.random.default_rng(4)
    steps = _mixed_steps(rng, n_steps=19)
    opts = HadesOptions(collect_every=4, backend=backend,
                        collector=col.CollectorConfig())

    h, _ = _drive_hades(opts, steps)

    e = eng.Engine(CFG, opts)
    state0 = e.init()
    assert jax.tree_util.tree_leaves(state0["bstate"]), \
        "stateful backend must seed a non-empty bstate"
    # run_window DONATES state0 — snapshot the seeded bstate first
    bstate0 = jax.tree.map(lambda x: np.asarray(x).copy(), state0["bstate"])
    state, outs, reports = e.run_window(state0, eng.make_trace(CFG, steps),
                                        0)
    _assert_state_equal(h.state, state)
    if "gen" in state["bstate"]:
        # mglru generations always age across windows; promote's state
        # evolution needs crafted stats (covered by the parity suite)
        moved = not np.array_equal(bstate0["gen"],
                                   np.asarray(state["bstate"]["gen"]))
        assert moved, "bstate never evolved across windows"


def test_record_access_padding_vs_object_zero():
    """Regression: a batch mixing padding (-1) with a genuine access to
    object 0 must still set object 0's access bit (invalid ids are
    dropped, not redirected to index 0 with a conflicting no-op write)."""
    tbl = ot.make_table(8)
    tbl = tbl.at[0].set(ot.pack(3, ot.NEW))
    got = ot.record_access(tbl, jnp.asarray([-1, 0, -1, -1], jnp.int32))
    assert int(ot.access_of(got[0])) == 1
    # and padding never dirties any other word
    assert np.array_equal(np.asarray(got[1:]), np.asarray(tbl[1:]))


def test_window_program_pre_fn_applies_lane_events_at_window_entry():
    """The pre_fn lane-event plumbing (continuous batching): events
    apply BEFORE the window-entry step — identically in the aligned and
    generic shapes, inside the same program — and event slices at
    non-entry steps are ignored."""
    import functools
    opts = _opts()
    backend = be.as_backend(opts.backend)
    run_generic, run_aligned = eng.window_program(
        functools.partial(eng._op_step, CFG),
        functools.partial(eng.collect_and_backend, CFG, opts.collector,
                          backend),
        col.arm, every=4,
        pre_fn=lambda s, ex: pl.free(CFG, s, ex["free"]))

    n = 16
    vals = np.arange(n * CFG.slot_words,
                     dtype=np.float32).reshape(n, CFG.slot_words)
    steps = [("alloc", np.arange(n), vals)] + \
        [("read", np.arange(6), None) for _ in range(7)]
    trace = eng.make_trace(CFG, steps)
    t = trace["op"].shape[0]
    # frees at the second window's ENTRY step (4); a free at a NON-entry
    # step (5) must be ignored by both shapes
    exs = {"free": jnp.full((t, 2), -1, jnp.int32)
           .at[4].set(jnp.asarray([14, 15], jnp.int32))
           .at[5].set(jnp.asarray([0, 1], jnp.int32))}

    def fresh():
        return dict(pl.init(CFG), bstate=backend.init(CFG))

    s_a, o_a, r_a = run_aligned(fresh(), trace, exs)
    s_g, o_g, r_g = run_generic(fresh(), trace, 0, exs)
    _assert_state_equal(s_a, s_g)
    assert np.array_equal(np.asarray(o_a), np.asarray(o_g))
    for k in r_a:
        assert np.array_equal(np.asarray(r_a[k]), np.asarray(r_g[k])), k
    heaps = np.asarray(ot.heap_of(s_a["table"][:n]))
    assert (heaps[14:] == ot.FREE).all(), "entry-step frees not applied"
    assert (heaps[:2] != ot.FREE).all(), "non-entry event was applied"
