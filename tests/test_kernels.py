"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import object_table as ot
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# migrate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_slots,w", [(32, 8), (64, 128), (40, 96)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_migrate_sweep(n_slots, w, dtype):
    data = jnp.asarray(RNG.integers(0, 100, (n_slots, w)).astype(dtype))
    n_moves = n_slots // 4
    src = jnp.asarray(RNG.choice(n_slots // 2, n_moves, replace=False),
                      jnp.int32)
    dst = jnp.asarray(n_slots // 2 +
                      RNG.choice(n_slots // 2, n_moves, replace=False),
                      jnp.int32)
    ok = jnp.asarray(RNG.random(n_moves) < 0.7)
    got = ops.migrate(data, src, dst, ok)
    want = ref.migrate(data, src, dst, ok)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_migrate_left_packing_order():
    """Compaction contract: dst[i] <= src[i], ascending — in-place safe."""
    data = jnp.arange(64, dtype=jnp.float32).reshape(16, 4)
    src = jnp.asarray([4, 6, 10, 14], jnp.int32)
    dst = jnp.asarray([0, 1, 2, 3], jnp.int32)
    ok = jnp.ones(4, bool)
    got = ops.migrate(data, src, dst, ok)
    want = ref.migrate(data, src, dst, ok)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# access_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,sb_slots,n_sbs", [(128, 8, 16), (384, 16, 64),
                                              (256, 32, 8)])
@pytest.mark.parametrize("ct", [0, 3, 30])
def test_access_scan_sweep(n, sb_slots, n_sbs, ct):
    tbl = ot.pack(
        jnp.asarray(RNG.integers(0, sb_slots * n_sbs, n), jnp.uint32),
        jnp.asarray(RNG.integers(0, 4, n), jnp.uint32),
        jnp.asarray(RNG.integers(0, 2, n), jnp.uint32),
        jnp.asarray(RNG.integers(0, 3, n), jnp.uint32),
        jnp.asarray(RNG.integers(0, 32, n), jnp.uint32))
    ctj = jnp.asarray(ct, jnp.uint32)
    got = ops.access_scan(tbl, ctj, sb_slots=sb_slots, n_sbs=n_sbs)
    want = ref.access_scan(tbl, ctj, sb_slots, n_sbs)
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))
    assert np.array_equal(np.asarray(got[2]), np.asarray(want[2]))
    assert np.array_equal(np.asarray(got[3]), np.asarray(want[3]))
    # skipped_atc is folded into the sweep (scalar ATC-veto count)
    assert int(got[4]) == int(want[4])


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,d", [(1, 128, 4, 4, 32),
                                        (2, 256, 4, 2, 64),
                                        (1, 256, 8, 1, 16)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, d, causal, window, dtype):
    q = _arr((b, s, h, d)).astype(dtype)
    k = _arr((b, s, kv, d)).astype(dtype)
    v = _arr((b, s, kv, d)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32),
                               causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert np.abs(np.asarray(got, np.float32)
                  - np.asarray(want, np.float32)).max() < tol


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,d,bt,mb", [(2, 8, 2, 16, 4, 6),
                                            (3, 4, 4, 32, 8, 4),
                                            (1, 8, 1, 64, 16, 3)])
def test_paged_attention_sweep(b, h, kv, d, bt, mb):
    n_slots = 32
    q = _arr((b, h, d))
    kp = _arr((n_slots, bt, kv, d))
    vp = _arr((n_slots, bt, kv, d))
    lens = jnp.asarray(RNG.integers(1, bt * mb, b), jnp.int32)
    tables = []
    for i in range(b):
        used = int(np.ceil(int(lens[i]) / bt))
        row = list(RNG.choice(n_slots, used, replace=False)) + \
            [-1] * (mb - used)
        tables.append(row)
    tables = jnp.asarray(tables, jnp.int32)
    got_o, got_t = ops.paged_attention(q, kp, vp, tables, lens)
    want_o, want_t = ref.paged_attention(q, kp, vp, tables, lens, bt)
    assert np.abs(np.asarray(got_o) - np.asarray(want_o)).max() < 2e-5
    assert np.array_equal(np.asarray(got_t), np.asarray(want_t))


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,c,n,chunk,ct", [(1, 64, 8, 16, 16, 4),
                                              (2, 128, 16, 8, 64, 8),
                                              (1, 32, 4, 4, 32, 4)])
def test_mamba_scan_sweep(b, s, c, n, chunk, ct):
    a = jnp.asarray(RNG.uniform(0.3, 1.0, (b, s, c, n)).astype(np.float32))
    bb = _arr((b, s, c, n))
    h0 = _arr((b, c, n))
    got_all, got_last = ops.mamba_scan(a, bb, h0, chunk=chunk, ct=ct)
    want_all, want_last = ref.mamba_scan(a, bb, h0)
    assert np.abs(np.asarray(got_all) - np.asarray(want_all)).max() < 1e-4
    assert np.abs(np.asarray(got_last) - np.asarray(want_last)).max() < 1e-4
