"""Trainer (resume/preemption/stragglers), checkpoint atomicity,
optimizer convergence, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.lm import DataConfig
from repro.models.model import build
from repro.optim import adamw, compression
from repro.runtime.trainer import Trainer, TrainerConfig


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.adamw_init(params)
    for _ in range(100):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw.adamw_update(cfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw.adamw_update(cfg, params, g, state)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = compression.compress_int8(g)
    back = compression.decompress_int8(q, s, g.shape, jnp.float32)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert rel < 0.02                           # int8 block quant error
    # error feedback accumulates the residual
    grads = {"w": g}
    red, err = compression.compressed_allreduce(grads, axis_name=None
                                                ) if False else (None, None)
    # (psum needs a mapped axis; unit-test the residual math directly)
    q2, s2 = compression.compress_int8(g)
    resid = g - compression.decompress_int8(q2, s2, g.shape, jnp.float32)
    assert float(jnp.abs(resid).max()) <= float(s2.max()) * 0.5 + 1e-6


def test_compressed_allreduce_under_shard_map():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("d",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.ones((n, 64), jnp.float32)}

    def f(gs):
        red, err = compression.compressed_allreduce(gs, "d")
        return red, err
    out, err = shard_map(f, mesh=mesh, in_specs=(P("d"),),
                         out_specs=P("d"))(g)
    # sum over n shards of ones = n (per row)
    assert np.allclose(np.asarray(out["w"]), n, atol=0.1)


def test_checkpoint_atomic_and_prunes():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones(3, jnp.bfloat16)}}
        for step in (1, 2, 3, 4):
            ckpt_lib.save(d, step, tree, keep_last=2)
        assert ckpt_lib.latest_step(d) == 4
        assert sorted(ckpt_lib.latest_steps(d)) == [3, 4]
        back = ckpt_lib.restore(d, 4, tree)
        assert np.array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
        assert back["b"]["c"].dtype == jnp.bfloat16
        # a stale .tmp dir is never listed as a checkpoint
        os.makedirs(os.path.join(d, "step_9.tmp"))
        assert ckpt_lib.latest_step(d) == 4


def test_checkpoint_elastic_reshard():
    """Restore applies NEW shardings to the stored (unsharded) arrays —
    the elastic-rescale path (512-chip save -> 256-chip restore)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
        ckpt_lib.save(d, 1, tree)
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data"))} if 4 % n == 0 else \
            {"w": NamedSharding(mesh, P())}
        back = ckpt_lib.restore(d, 1, tree, shardings=sh)
        assert np.array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
        assert back["w"].sharding == sh["w"]


def test_trainer_resume_and_preemption():
    m = build("chatglm3-6b", reduced=True)
    dcfg = DataConfig(vocab_size=m.cfg.vocab_size, seq_len=16,
                      global_batch=2)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(ckpt_dir=d, ckpt_every=4, log_every=2)
        ocfg = adamw.AdamWConfig(total_steps=20, warmup_steps=2)
        tr = Trainer(m, dcfg, ocfg, tcfg)
        out = tr.run(m.init(jax.random.PRNGKey(0)), num_steps=6)
        assert out["step"] == 6
        # simulated preemption: handler sets the flag mid-run
        tr2 = Trainer(m, dcfg, ocfg, tcfg)
        tr2._preempted = True
        out2 = tr2.run(m.init(jax.random.PRNGKey(1)), num_steps=12)
        assert out2["preempted"] and out2["step"] == 6  # saved, no steps
        # a fresh trainer resumes from 6 and continues
        tr3 = Trainer(m, dcfg, ocfg, tcfg)
        out3 = tr3.run(m.init(jax.random.PRNGKey(2)), num_steps=10)
        assert out3["step"] == 10


def test_server_generate_and_collect():
    from repro.runtime.server import Server, ServerConfig
    m = build("chatglm3-6b", reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    srv = Server(m, ServerConfig(batch=2, max_len=32, block_tokens=4,
                                 collect_every=6))
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = srv.generate(params, prompts, max_new=10)
    assert out.shape == (2, 10)
    assert len(srv.reports) >= 1
    assert srv.kv_rss_bytes() > 0
