"""Donated window buffers: every window entry point (`Engine.run_window`
/ `serve_steps` / `Engine.step` / the server's decode programs) donates
the incoming pool state, so the pool — notably `data`,
(n_slots+1) x slot_words — is updated in place instead of being
double-buffered per dispatch.

The regression surface is the CALLER contract: a donated state is
consumed, so (a) the framework's own paths (`Hades`, `Engine.step`,
`Server`) must never touch a state after passing it in, (b) streaming
(`serve_steps`, `generate`) must keep working across chained donations,
and (c) an external caller reusing a donated state must fail loudly
(deleted buffer), not read garbage."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Hades, HadesOptions, make_config
from repro.core import collector as col
from repro.core import engine as eng
from repro.core.backend import BackendConfig

CFG = make_config(max_objects=64, slot_words=8, sb_slots=8, page_slots=4,
                  slack=2.0)


def _opts(every=4):
    return HadesOptions(collect_every=every,
                        backend=BackendConfig(kind="proactive"),
                        collector=col.CollectorConfig())


def _steps(n_objs=32, n_steps=11):
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(n_objs, CFG.slot_words)).astype(np.float32)
    steps = [("alloc", np.arange(n_objs), vals)]
    for _ in range(n_steps):
        steps.append(("read", rng.integers(0, n_objs, 6), None))
    return steps, vals


def test_run_window_consumes_state():
    """The fused window donates its state input: the passed-in pytree is
    deleted (updated in place, not copied) and reuse fails loudly."""
    e = eng.Engine(CFG, _opts())
    steps, _ = _steps()
    trace = eng.make_trace(CFG, steps)
    s0 = e.init()
    s1, _, _ = e.run_window(s0, trace, 0)
    jax.block_until_ready(s1["table"])
    assert all(leaf.is_deleted()
               for leaf in jax.tree_util.tree_leaves(s0)), \
        "donation did not engage: the input pool was copied, not reused"
    with pytest.raises((RuntimeError, ValueError)):
        e.run_window(s0, trace, 0)           # reuse must fail, not alias
    # the returned state is alive and chains into the next window
    s2, _, _ = e.run_window(s1, trace, len(steps))
    assert not any(leaf.is_deleted()
                   for leaf in jax.tree_util.tree_leaves(s2))


def test_hades_per_op_path_never_reuses_donated_state():
    """`Hades`/`Engine.step` reassign their state on every op — a long
    op/collect/metric sequence works and reads back correct payloads."""
    h = Hades(CFG, _opts())
    steps, vals = _steps(n_steps=13)
    for op, ids, values in steps:
        if op == "alloc":
            h.alloc(ids, values)
        else:
            got = h.read(ids)
            assert np.allclose(np.asarray(got), vals[ids])
    h.collect()                               # forced collect_now path
    assert h.rss_bytes() > 0                  # metrics on the live state
    assert h.heap_histogram()["hot"] + h.heap_histogram()["new"] + \
        h.heap_histogram()["cold"] == 32
    got = h.read(np.arange(32))
    assert np.allclose(np.asarray(got), vals)


def test_serve_steps_streams_with_donation():
    """Streaming chains donations window-to-window: results and reports
    are identical to the one-shot scan (each from its own fresh init)."""
    steps, _ = _steps(n_steps=15)
    trace = eng.make_trace(CFG, steps)
    e = eng.Engine(CFG, _opts())
    s1, o1, r1 = e.run_window(e.init(), trace, 0)
    s2, o2, reps = e.serve_steps(e.init(), trace)
    for (path, x), y in zip(
            jax.tree_util.tree_flatten_with_path(s1)[0],
            jax.tree_util.tree_leaves(s2)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"state{jax.tree_util.keystr(path)} diverged"
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert len(reps) == len(steps) // 4
    assert all(r["did_collect"] for r in reps)


def test_server_decode_paths_never_reuse_donated_carry():
    """The server's three programs (step / aligned window / generic
    window) all donate the decode carry; generate streams across them
    and the previous window's pool buffers are actually released."""
    from repro.models.model import build
    from repro.runtime.server import Server, ServerConfig

    m = build("chatglm3-6b", reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    srv = Server(m, ServerConfig(batch=2, max_len=32, block_tokens=4,
                                 collect_every=4))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, m.cfg.vocab_size, (2, 3)),
                          jnp.int32)

    out = srv.generate(params, prompts, max_new=6)
    assert out.shape == (2, 6)
    pool_before = srv.state["pool"]["data"]

    toks = jnp.asarray(rng.integers(0, m.cfg.vocab_size, (2,)), jnp.int32)
    srv.decode_step(params, toks)             # donates the held carry
    assert pool_before.is_deleted(), \
        "decode did not donate the previous pool buffer"
    # generic (non-aligned) window after the step still works
    logits, sampled, _ = srv.decode_window(params, toks[:, None])
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert srv.kv_rss_bytes() > 0
