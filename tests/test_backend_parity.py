"""Backend one-oracle parity.

1. Trace-driven suite: every registered backend, run under `jax.jit`
   with its carried state threaded through a `lax.scan` (exactly how the
   Engine runs it inside the fused window), must match the SimHeap page
   adapter (the same implementation, eager with numpy inputs) on shared
   traces — pressure, calm, and fragmented-address-space scenarios.
2. Synthetic-stats suite: multi-window jit-scan vs eager parity on
   randomized superblock stats (covers the promote promotion path,
   which the simulator can't reach — loads fault HOST pages back in).
3. Bit-parity of the four ported backends against the pre-refactor
   `backend.step` logic (reimplemented here verbatim as the reference).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.core import pool as pl
from repro.core.simheap import PAGE, SimConfig, SimHeap

ALL_BACKENDS = ("reactive", "proactive", "cap", "null", "mglru", "promote")


# ---------------------------------------------------------------------------
# 1. shared SimHeap traces, replayed through the jitted scan
# ---------------------------------------------------------------------------
def _drive(h: SimHeap, scenario: str, seed: int = 0):
    """Run a scenario, recording the backend protocol inputs/outputs at
    every window. Returns (trace dict of stacked inputs, list of
    post-step (tier, evict))."""
    rng = np.random.default_rng(seed)
    n = 160
    h.alloc(np.arange(n), rng.integers(64, 2048, n))
    ins, outs = [], []
    for w in range(8):
        if scenario == "pressure":
            hot = rng.integers(0, n // 8, 24)          # tiny hot set
        elif scenario == "calm":
            hot = rng.integers(0, n, 96)               # touch most
        else:                                          # fragmented
            hot = (rng.integers(0, n // 2, 24) * 2) % n  # scattered
            if w == 2:                                 # punch holes
                dead = [i for i in range(1, n, 3) if h.heap[i] >= 0]
                h.free(np.asarray(dead))
        live = hot[h.heap[hot] >= 0]
        if len(live):
            h.access_objects(live)
        h.arm()
        h.collect()
        stats, tier, evict = h.page_stats()
        ins.append({"stats": stats, "tier": tier, "evict": evict,
                    "ok": np.bool_(h.proactive_ok),
                    "epoch": np.int32(h.epoch)})
        h.backend_step()
        post_tier = np.where(h.evict == 2, pl.HOST, pl.HBM).astype(np.int8)
        outs.append((post_tier, h.evict.copy()))
    trace = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *ins)
    return trace, outs


def _replay_jit(backend: be.Backend, geom, trace):
    """The Engine's execution shape: one jitted lax.scan, bstate in the
    carry."""
    def body(bstate, xs):
        bstate, tier, evict, telem = backend.step(
            geom, bstate, xs["stats"], xs["tier"], xs["evict"],
            {"proactive_ok": xs["ok"], "epoch": xs["epoch"]})
        return bstate, {"tier": tier, "evict": evict}

    @jax.jit
    def run(trace):
        return jax.lax.scan(body, backend.init(geom), trace)

    _, ys = run(trace)
    return np.asarray(ys["tier"]), np.asarray(ys["evict"])


@pytest.mark.parametrize("scenario", ["pressure", "calm", "fragmented"])
@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_jit_backend_matches_simheap_oracle(name, scenario):
    """jit scan-carried execution == the SimHeap adapter's eager run on
    the same trace, for every registered backend. The demotion/promotion
    deltas the simulator applies to its page metadata must be exactly
    the (tier, evict) columns the jitted backend emits."""
    cfg = SimConfig(max_objects=512, heap_bytes=1 << 19, backend=name,
                    hbm_target_bytes=1 << 16 if scenario == "pressure"
                    else 1 << 18)
    h = SimHeap(cfg, seed=0)
    trace, sim_outs = _drive(h, scenario)
    geom = be.PageGeometry(n_sbs=h.n_pages, sb_bytes=PAGE)
    jit_tier, jit_evict = _replay_jit(h._make_backend(cfg), geom, trace)
    for w, (sim_tier, sim_evict) in enumerate(sim_outs):
        assert np.array_equal(jit_tier[w], sim_tier), \
            f"{name}/{scenario}: tier diverged at window {w}"
        assert np.array_equal(jit_evict[w], sim_evict), \
            f"{name}/{scenario}: evict diverged at window {w}"


# ---------------------------------------------------------------------------
# 2. synthetic stats: jit-scan vs eager, promotion path included
# ---------------------------------------------------------------------------
def _random_stats_trace(rng, n_sbs, t):
    return {
        "stats": {
            "occupancy": jnp.asarray(
                rng.integers(0, 4, (t, n_sbs)), jnp.int32),
            "referenced": jnp.asarray(rng.random((t, n_sbs)) < 0.5),
            "region": jnp.asarray(
                rng.integers(0, 3, (t, n_sbs)), jnp.int8),
            "tier": jnp.zeros((t, n_sbs), jnp.int8),
            "evict": jnp.zeros((t, n_sbs), jnp.int8),
        },
        "tier": jnp.asarray(rng.integers(0, 2, (t, n_sbs)), jnp.int8),
        "evict": jnp.asarray(rng.integers(0, 3, (t, n_sbs)), jnp.int8),
        "ok": jnp.asarray(rng.random(t) < 0.5),
        "epoch": jnp.arange(t, dtype=jnp.int32),
    }


@pytest.mark.parametrize("name,params", [
    ("mglru", dict(hbm_target_bytes=6 * 4096)),
    ("promote", dict(hbm_high_bytes=10 * 4096, hbm_low_bytes=5 * 4096,
                     promote_after=2)),
    ("reactive", dict(hbm_target_bytes=6 * 4096)),
    ("proactive", {}),
])
def test_jit_scan_matches_eager_on_synthetic_stats(name, params):
    """Stateful carry under jit == the eager python loop, on stats rich
    enough to hit every branch (referenced HOST superblocks exercise
    promote's promotion + hysteresis)."""
    n_sbs, t = 16, 10
    geom = be.PageGeometry(n_sbs=n_sbs, sb_bytes=4096)
    backend = be.make(name, **params)
    trace = _random_stats_trace(np.random.default_rng(7), n_sbs, t)

    jit_tier, jit_evict = _replay_jit(backend, geom, trace)

    bstate = backend.init(geom)
    promoted_any = 0
    for w in range(t):
        xs = jax.tree.map(lambda v: v[w], trace)
        bstate, tier, evict, telem = backend.step(
            geom, bstate, xs["stats"], xs["tier"], xs["evict"],
            {"proactive_ok": xs["ok"], "epoch": xs["epoch"]})
        promoted_any += int(telem["be_promoted"])
        assert np.array_equal(np.asarray(tier), jit_tier[w]), (name, w)
        assert np.array_equal(np.asarray(evict), jit_evict[w]), (name, w)
    if name == "promote":
        assert promoted_any > 0, "synthetic trace never promoted"


# ---------------------------------------------------------------------------
# 3. the four ported backends vs the pre-refactor implementation
# ---------------------------------------------------------------------------
def _legacy_demote_k(tier, evict, victim_priority, k):
    """Verbatim pre-refactor `_demote_k` (the recorded reference)."""
    n = tier.shape[0]
    order = jnp.argsort(-victim_priority)
    ranked_prio = victim_priority[order]
    take = (jnp.arange(n) < k) & (ranked_prio > 0)
    chosen = jnp.zeros((n,), jnp.bool_).at[order].set(take)
    tier = jnp.where(chosen, pl.HOST, tier).astype(jnp.int8)
    evict = jnp.where(chosen, pl.PAGED_OUT, evict).astype(jnp.int8)
    return tier, evict


def _legacy_step(kind, hbm_target_bytes, pool_cfg, stats, tier, evict,
                 proactive_ok):
    """Verbatim pre-refactor `backend.step` (the recorded reference)."""
    occ = stats["occupancy"]
    ref = stats["referenced"]
    resident = (occ > 0) & (tier == pl.HBM)
    if kind == "null":
        return tier, evict
    if kind == "proactive":
        do = resident & (evict == pl.CANDIDATE) & proactive_ok
        tier = jnp.where(do, pl.HOST, tier).astype(jnp.int8)
        evict = jnp.where(do, pl.PAGED_OUT, evict).astype(jnp.int8)
        return tier, evict
    target_sbs = max(hbm_target_bytes, 0) // pool_cfg.sb_bytes
    k = jnp.maximum(jnp.sum(resident).astype(jnp.int32) - target_sbs, 0)
    if kind == "reactive":
        prio = jnp.where(resident,
                         jnp.where(evict == pl.CANDIDATE, 3,
                                   jnp.where(~ref, 2, 1)), 0)
        return _legacy_demote_k(tier, evict, prio, k)
    if kind == "cap":
        n = tier.shape[0]
        prio = jnp.where(resident, n - jnp.arange(n), 0)
        return _legacy_demote_k(tier, evict, prio, k)
    raise ValueError(kind)


PCFG = pl.make_config(max_objects=256, slot_words=4, sb_slots=8, slack=1.0)


@pytest.mark.parametrize("kind", ["reactive", "proactive", "cap", "null"])
def test_ported_backends_bit_identical_to_prerefactor(kind):
    rng = np.random.default_rng(11)
    n = PCFG.n_sbs
    for trial in range(20):
        target = int(rng.integers(0, n + 4)) * PCFG.sb_bytes
        stats = {
            "occupancy": jnp.asarray(rng.integers(0, 3, n), jnp.int32),
            "referenced": jnp.asarray(rng.random(n) < 0.5),
            "region": jnp.asarray(rng.integers(0, 3, n), jnp.int8),
            "tier": jnp.zeros((n,), jnp.int8),
            "evict": jnp.zeros((n,), jnp.int8),
        }
        tier = jnp.asarray(rng.integers(0, 2, n), jnp.int8)
        evict = jnp.asarray(rng.integers(0, 3, n), jnp.int8)
        ok = jnp.asarray(bool(rng.integers(0, 2)))

        want_t, want_e = _legacy_step(kind, target, PCFG, stats, tier,
                                      evict, ok)
        backend = be.BackendConfig(kind=kind,
                                   hbm_target_bytes=target).build()
        _, got_t, got_e, _ = backend.step(
            PCFG, backend.init(PCFG), stats, tier, evict,
            {"proactive_ok": ok, "epoch": jnp.asarray(trial)})
        assert np.array_equal(np.asarray(want_t), np.asarray(got_t)), \
            (kind, trial)
        assert np.array_equal(np.asarray(want_e), np.asarray(got_e)), \
            (kind, trial)
