"""Sharding-rule sanity on an AbstractMesh (no fake devices needed):
every param leaf of every arch gets a legal PartitionSpec (divisibility
respected), batch/pod axes behave, decode caches shard B/data + C/model."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES
from repro.launch import shardings as sh
from repro.models.model import Model

def _amesh(axis_sizes, axis_names):
    """AbstractMesh across jax versions: new API takes (sizes, names),
    jax<=0.4.x takes a ((name, size), ...) shape tuple."""
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


MESH = _amesh((16, 16), ("data", "model"))
MESH3 = _amesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(shape, spec, axis_sizes):
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        total = 1
        for a in axes:
            total *= axis_sizes[a]
        assert dim % total == 0, f"{shape} {spec}: {dim} % {total}"


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_legal(arch):
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = model.param_specs()
    axis_sizes = {"data": 16, "model": 16}

    def one(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = sh.param_spec(MESH, pstr, leaf.shape)
        assert len(spec) <= len(leaf.shape)
        padded = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        _check_divisible(leaf.shape, padded, axis_sizes)
        return spec
    jax.tree_util.tree_map_with_path(one, shapes)


@pytest.mark.parametrize("arch", ["glm4-9b", "mixtral-8x7b",
                                  "qwen2-vl-72b", "granite-34b"])
def test_big_matrices_are_2d_sharded(arch):
    """FSDP x TP: the large weights must shard on BOTH mesh axes."""
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = model.param_specs()
    found_2d = []

    def one(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = sh.param_spec(MESH, pstr, leaf.shape)
        axes = {a for e in spec if e is not None
                for a in ((e,) if isinstance(e, str) else e)}
        if {"data", "model"} <= axes:
            found_2d.append(pstr)
    jax.tree_util.tree_map_with_path(one, shapes)
    assert len(found_2d) >= 3, f"{arch}: too few 2D-sharded weights"


def test_batch_spec_pod_axis():
    spec = sh.batch_spec(MESH3, 2)
    assert spec[0] == ("pod", "data")
    spec1 = sh.batch_spec(MESH, 2)
    assert spec1[0] in ("data", ("data",))  # P() normalizes 1-tuples


@pytest.mark.parametrize("arch,shape", [("glm4-9b", "decode_32k"),
                                        ("granite-34b", "decode_32k"),
                                        ("zamba2-2.7b", "long_500k")])
def test_decode_cache_shardings(arch, shape):
    cfg = get_config(arch)
    model = Model(cfg)
    spec = SHAPES[shape]
    specs = model.input_specs(spec)
    state_shape = specs["state"]
    shd = sh.decode_state_shardings(MESH, state_shape, cfg)

    def check(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        s = jax.tree.leaves(
            shd, is_leaf=lambda x: hasattr(x, "spec"))
    k_shd = shd["kv"]["k"].spec if "kv" in shd else None
    if k_shd is not None:
        l, b, c, kv, d = jax.tree.leaves(
            state_shape["kv"]["k"], is_leaf=lambda x: hasattr(x, "shape")
        )[0].shape
        if b % 16 == 0:
            assert k_shd[1] == "data"
        if c % 16 == 0:
            assert k_shd[2] == "model"


def test_per_device_bytes_fit_hbm():
    """Analytic arg budget (params+opt or params+cache) must fit 16 GiB
    on the single-pod mesh for the heaviest cells."""
    import json
    import glob
    import os
    recs = []
    for f in glob.glob("experiments/dryrun/*_pod256.json"):
        with open(f) as fh:
            recs.append(json.load(fh))
    if not recs:
        pytest.skip("dry-run artifacts not present")
    for r in recs:
        if "arg_bytes_per_device_analytic" not in r:
            continue
        gib = r["arg_bytes_per_device_analytic"] / 2 ** 30
        assert gib < 16.0, f"{r['cell']}: {gib:.1f} GiB/device args"
