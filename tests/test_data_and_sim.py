"""YCSB structures, zipfian streams, SimHeap/CrestKV end-to-end, and the
LM pipeline determinism."""
import numpy as np
import pytest

from repro.core.simheap import PAGE, SimConfig, SimHeap
from repro.data.crestkv import CrestKV, default_sim_config
from repro.data.structures import STRUCTURES, make_structure
from repro.data.ycsb import WORKLOADS, ZipfianKeys, ops_stream


def test_zipfian_skew_and_scatter():
    z = ZipfianKeys(10_000, seed=0)
    ks = z.sample(50_000)
    _, counts = np.unique(ks, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[0] > 50 * np.median(counts)     # heavy head
    hot = z.hot_set(0.5)
    assert hot.std() > 10_000 / 5              # scattered across keyspace


def test_active_frac_limits_support():
    z = ZipfianKeys(10_000, seed=0, active_frac=0.2)
    ks = z.sample(200_000)
    assert len(np.unique(ks)) <= 2_000


def test_ops_stream_deterministic():
    z1 = ZipfianKeys(1000, seed=3)
    z2 = ZipfianKeys(1000, seed=3)
    a = list(ops_stream(WORKLOADS["A"], z1, 5000, seed=3))
    b = list(ops_stream(WORKLOADS["A"], z2, 5000, seed=3))
    for (u1, k1), (u2, k2) in zip(a, b):
        assert np.array_equal(u1, u2) and np.array_equal(k1, k2)
    upd_frac = np.concatenate([u for u, _ in a]).mean()
    assert 0.4 < upd_frac < 0.6


@pytest.mark.parametrize("name", sorted(STRUCTURES))
def test_structure_topologies(name):
    s = make_structure(name, 512, seed=0)
    keys = np.asarray([0, 1, 255, 511])
    upd = np.asarray([False, True, False, True])
    vo = 10_000 + keys
    flat = s.touched(keys, upd, vo)
    assert (flat >= 0).all()
    # deterministic
    assert np.array_equal(flat, s.touched(keys, upd, vo))
    # includes the key and value objects
    for k, v in zip(keys, vo):
        assert k in flat and v in flat
    # paths touch index metadata too
    assert (flat >= s.meta_base).sum() > 0 or name == "hash-harris"


def test_coarse_lock_is_a_shared_hot_object():
    s = make_structure("skip-coarse", 256, seed=0)
    keys = np.arange(64)
    flat = s.touched(keys, np.zeros(64, bool), 10_000 + keys)
    # the global lock object is touched once by EVERY op (the skiplist
    # head node is the only comparably hot object)
    assert (flat == s.lock_base).sum() == 64
    # fraser (lock-free) touches no metadata objects (values live at
    # ids >= 10_000 in this test — exclude them)
    s2 = make_structure("skip-fraser", 256, seed=0)
    flat2 = s2.touched(keys, np.zeros(64, bool), 10_000 + keys)
    assert ((flat2 >= s2.meta_base) & (flat2 < 10_000)).sum() == 0


def test_simheap_alloc_access_collect():
    cfg = SimConfig(max_objects=1000, heap_bytes=1 << 22,
                    backend="proactive")
    h = SimHeap(cfg)
    ids = np.arange(100)
    h.alloc(ids, np.full(100, 128))
    h.access_objects(ids[:10])
    rep = h.collect()
    assert 0 < rep["page_utilization"] <= 1
    assert h.heap[:10].max() >= 0
    # content-free invariant: addresses unique & non-overlapping
    order = np.argsort(h.addr[:100])
    a = h.addr[:100][order]
    sz = (h.size[:100][order] + 15) // 16 * 16
    assert (a[1:] >= a[:-1] + sz[:-1]).all()


def test_crestkv_hades_beats_baseline():
    """The paper's headline at mini scale: tidying raises page
    utilization and cuts RSS with small overhead."""
    n = 20_000
    base = CrestKV("hash-pugh", n,
                   default_sim_config(n, backend="null", enabled=False),
                   seed=0)
    sb = base.run("C", 400_000, window_ops=80_000)
    hades = CrestKV("hash-pugh", n,
                    default_sim_config(n, backend="proactive",
                                       enabled=True), seed=0)
    sh = hades.run("C", 400_000, window_ops=80_000)
    pu_base = sb.windows[-1]["page_utilization"]
    pu_hades = sh.windows[-1]["page_utilization"]
    assert pu_hades > 1.5 * pu_base
    assert sh.windows[-1]["rss_bytes"] < 0.7 * sb.windows[-1]["rss_bytes"]
    assert sh.overhead_frac < 0.10


def test_crestkv_updates_churn():
    n = 5_000
    kv = CrestKV("btree-occ", n,
                 default_sim_config(n, backend="reactive",
                                    hbm_target_bytes=1 << 22), seed=0)
    st = kv.run("A", 100_000, window_ops=25_000)
    assert st.ops == 100_000
    assert len(st.windows) >= 3


def test_lm_pipeline_deterministic_and_sharded():
    from repro.data.lm import DataConfig, TokenPipeline
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    p0 = TokenPipeline(cfg, shard=0, num_shards=2)
    p0b = TokenPipeline(cfg, shard=0, num_shards=2)
    p1 = TokenPipeline(cfg, shard=1, num_shards=2)
    b0 = p0.batch_at(5)
    assert np.array_equal(np.asarray(b0["tokens"]),
                          np.asarray(p0b.batch_at(5)["tokens"]))
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(p1.batch_at(5)["tokens"]))
    assert np.asarray(b0["tokens"]).shape == (4, 16)
    # labels are next-token shifted
    full0 = np.asarray(b0["tokens"])[:, 1:]
    assert np.array_equal(full0, np.asarray(b0["labels"])[:, :-1])
