"""KV-cache / embedding / expert tiering integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import embedding as emb
from repro.models import expert_tiering as et
from repro.models import kvcache as kvc

CFG = kvc.KVCacheConfig(num_layers=2, batch=3, max_blocks=8,
                        block_tokens=4, num_kv_heads=2, head_dim=16,
                        dtype="float32")


def _fill(state, steps, rng):
    ks, vs = [], []
    for _ in range(steps):
        k = jnp.asarray(rng.normal(size=(2, 3, 2, 16)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 3, 2, 16)).astype(np.float32))
        ks.append(k)
        vs.append(v)
        state = kvc.append(CFG, state, k, v)
    return state, ks, vs


def test_paged_attend_matches_dense(rng):
    state, ks, vs = _fill(kvc.init(CFG), 11, rng)
    q = jnp.asarray(rng.normal(size=(3, 4, 16)).astype(np.float32))
    for layer in (0, 1):
        out, state = kvc.attend(CFG, state, layer, q)
        K = jnp.stack([k[layer] for k in ks], axis=1)
        V = jnp.stack([v[layer] for v in vs], axis=1)
        want = attn.decode_attention(q[:, None], K, V,
                                     jnp.full((3,), 11))[:, 0]
        assert np.abs(np.asarray(out) - np.asarray(want)).max() < 2e-5


def test_migration_transparent_to_serving(rng):
    """Collector passes between decode steps must not change attention
    results (the paper's pointer-update guarantee)."""
    state, ks, vs = _fill(kvc.init(CFG), 9, rng)
    q = jnp.asarray(rng.normal(size=(3, 4, 16)).astype(np.float32))
    out0, state = kvc.attend(CFG, state, 1, q)
    # several collector passes (some armed) migrate blocks around
    for i in range(5):
        if i % 2:
            state = kvc.arm(state)
        state, rep = kvc.collect(CFG, state)
    out1, state = kvc.attend(CFG, state, 1, q)
    assert np.abs(np.asarray(out0) - np.asarray(out1)).max() < 1e-5
    assert int(state["pool"]["total_moves"]) > 0, "nothing migrated"


def test_kv_cold_blocks_demote(rng):
    """Blocks never touched again drift to COLD; hot blocks stay dense."""
    from repro.core import object_table as ot
    state, _, _ = _fill(kvc.init(CFG), 32, rng)  # 8 blocks per (L,seq)
    q = jnp.asarray(rng.normal(size=(3, 4, 16)).astype(np.float32))
    # attend only with a short suffix window by shrinking pos? instead:
    # touch all (attend) once, then collect repeatedly with no access.
    out, state = kvc.attend(CFG, state, 0, q)
    for _ in range(6):
        state, rep = kvc.collect(CFG, state)
    tbl = state["pool"]["table"]
    heaps = np.asarray(ot.heap_of(tbl))
    live = heaps != ot.FREE
    assert (heaps[live] == ot.COLD).mean() > 0.9


def test_embedding_cache_coherence(rng):
    cfg = emb.TieredEmbeddingConfig(vocab_size=64, d_model=8, hot_rows=8)
    table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    s = emb.init(cfg, table)
    toks = jnp.asarray(rng.integers(0, 64, size=(3, 7)), jnp.int32)
    out, s = emb.lookup(cfg, s, toks)
    assert np.allclose(np.asarray(out), np.asarray(table)[np.asarray(toks)])
    # training write: both tiers see the update
    rows = jnp.asarray([0, 33], jnp.int32)
    vals = jnp.ones((2, 8), jnp.float32) * 5
    s = emb.write_rows(s, rows, vals)
    out, s = emb.lookup(cfg, s, rows)
    assert np.allclose(np.asarray(out), 5.0)
    # collect re-elects hot set; reads stay correct
    s, rep = emb.collect(cfg, s)
    out, s = emb.lookup(cfg, s, toks)
    want = np.asarray(s["full"])[np.asarray(toks)]
    assert np.allclose(np.asarray(out), want)
    assert 0 <= float(rep["hot_coverage"]) <= 1


def test_expert_tiering_demotes_and_faults():
    cfg = et.ExpertTieringConfig(num_layers=2, num_experts=8,
                                 bytes_per_expert=100)
    s = et.init(cfg)
    hot = jnp.zeros((2, 8), jnp.int32).at[:, :2].set(50)
    for _ in range(6):
        s = et.observe(cfg, s, hot)
        s, rep = et.collect(cfg, s)
    assert int(rep["resident_experts"]) == 4          # 2 per layer
    # a token routed to a cold expert faults its slab back
    probe = jnp.zeros((2, 8), jnp.int32).at[0, 7].set(1)
    s = et.observe(cfg, s, probe)
    assert int(s["total_faults"]) >= 1
    assert bool(s["resident"][0, 7])
