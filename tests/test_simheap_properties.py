"""Property-based invariants for the byte-granular SimHeap (the paper's
evaluation substrate): under ANY interleaving of alloc/access/free/
collect/backend ops, the address space stays consistent."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.simheap import ALIGN, NEW, PAGE, SimConfig, SimHeap

ops = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 20),
                  st.integers(16, 2048)),
        st.tuples(st.just("access"), st.integers(0, 199)),
        st.tuples(st.just("free"), st.integers(0, 199)),
        st.tuples(st.just("collect"), st.just(0)),
        st.tuples(st.just("backend"), st.just(0)),
    ), min_size=5, max_size=40)


def check_no_overlap(h: SimHeap):
    live = np.nonzero(h.heap >= 0)[0]
    if len(live) == 0:
        return
    order = np.argsort(h.addr[live])
    a = h.addr[live][order]
    sz = (h.size[live][order] + ALIGN - 1) // ALIGN * ALIGN
    assert (a[1:] >= a[:-1] + sz[:-1]).all(), "live objects overlap"
    # every live object lies inside its heap's address range
    for i in live:
        hp = int(h.heap[i])
        base = h.base[hp]
        assert base <= h.addr[i] < base + h.cfg.heap_bytes
        assert h.addr[i] + h.size[i] <= base + h.cfg.heap_bytes


@settings(max_examples=30, deadline=None)
@given(ops, st.sampled_from(["reactive", "proactive", "cap", "null"]))
def test_simheap_invariants_any_interleaving(op_list, backend):
    cfg = SimConfig(max_objects=256, heap_bytes=1 << 20, backend=backend,
                    hbm_target_bytes=1 << 18)
    h = SimHeap(cfg, seed=0)
    next_id = 0
    live_ids = set()
    for op in op_list:
        if op[0] == "alloc":
            _, n, size = op
            n = min(n, 256 - next_id)
            if n <= 0:
                continue
            ids = np.arange(next_id, next_id + n)
            h.alloc(ids, np.full(n, size))
            live_ids.update(ids.tolist())
            next_id += n
        elif op[0] == "access":
            if live_ids:
                pick = [i for i in (op[1], op[1] // 2) if i in live_ids]
                if pick:
                    h.access_objects(np.asarray(pick))
        elif op[0] == "free":
            if op[1] in live_ids:
                h.free(np.asarray([op[1]]))
                live_ids.discard(op[1])
        elif op[0] == "collect":
            rep = h.collect()
            assert 0 <= rep["promotion_rate"] <= 1
            assert 0 < rep["page_utilization"] <= 1
            assert cfg.ciw_min <= h.ciw_threshold <= cfg.ciw_max
        elif op[0] == "backend":
            h.backend_step()
        check_no_overlap(h)
    # accounting: rss never exceeds the mapped address space
    assert 0 <= h.rss_bytes() <= 3 * cfg.heap_bytes + 2 * (1 << 21)
    # live-byte ledgers never go negative
    assert all(v >= 0 for v in h.live_bytes.values())


def test_simheap_emergency_compact_charges_faults():
    """Compacting a region with paged-out pages must fault them in and
    say so (the honesty rule for COLD compaction)."""
    cfg = SimConfig(max_objects=64, heap_bytes=1 << 16,
                    backend="proactive")
    h = SimHeap(cfg, seed=0)
    h.alloc(np.arange(32), np.full(32, 1024))
    # cool everything into COLD and page it out
    for _ in range(6):
        h.collect()
        h.backend_step()
    paged = int((h.evict == 2).sum())
    if paged == 0:
        pytest.skip("backend never paged out at this scale")
    before = h.total_faults
    h._compact(2)  # COLD heap emergency compaction
    assert h.total_faults > before
