"""Correctness of the §Perf beyond-paper variants: the optimizations
must not change the math (or must bound their error)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import moe as moe_lib


def _moe_cfg():
    return get_config("mixtral-8x7b", reduced=True)


def test_expert_gather_matches_dense_dispatch(rng):
    """moe_block_gathered (HADES hot-expert weight stream) is exact vs
    the dense reference for small T."""
    cfg = _moe_cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 1, cfg.d_model))
                    .astype(np.float32))
    got, aux, counts = moe_lib.moe_block_gathered(p, x, cfg)
    want = moe_lib.moe_block_ref(p, x, cfg)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() < 1e-5
    assert int(counts.sum()) == cfg.experts_per_token


def test_expert_gather_used_only_when_profitable():
    """decode uses the gathered path iff T*k < E (else dispatch wins)."""
    cfg = _moe_cfg()
    assert 1 * cfg.experts_per_token < cfg.num_experts       # B=1: gather
    assert not (64 * cfg.experts_per_token < cfg.num_experts)  # B=64: no


def test_moe_sharding_hints_do_not_change_math(rng):
    """with_sharding_constraint is semantics-preserving; on a 1-device
    mesh the hinted block must be bit-identical."""
    cfg = _moe_cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model))
                    .astype(np.float32))
    base, _, _ = jax.jit(lambda: moe_lib.moe_block(p, x, cfg))()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    moe_lib.set_sharding_hints({"dispatch": P(None, "data", None),
                                "hidden": P(None, "data", "model")})
    try:
        with mesh:
            hinted, _, _ = jax.jit(lambda: moe_lib.moe_block(p, x, cfg))()
    finally:
        moe_lib.set_sharding_hints(None)
    assert np.array_equal(np.asarray(base), np.asarray(hinted))


def test_int8_kv_quantization_error_bounded(rng):
    """int8 per-block-scale KV: decode attention output error stays
    small (the kv8 §Perf variant's numerical feasibility)."""
    b, s, kv, d = 2, 64, 2, 32
    q = jnp.asarray(rng.normal(size=(b, 1, 4, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))

    def quant(x, block=16):
        xb = np.asarray(x).reshape(b, s // block, block, kv, d)
        scale = np.abs(xb).max(axis=(2, 4), keepdims=True) / 127.0
        qx = np.clip(np.round(xb / np.maximum(scale, 1e-9)), -127, 127)
        return jnp.asarray((qx * scale).reshape(b, s, kv, d)
                           .astype(np.float32))

    want = attn.decode_attention(q, k, v, jnp.full((b,), s))
    got = attn.decode_attention(q, quant(k), quant(v), jnp.full((b,), s))
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    scale_ref = np.abs(np.asarray(want)).max()
    assert err < 0.05 * scale_ref, f"int8 KV error {err} vs {scale_ref}"


def test_hades_flags_default_off():
    """The paper-faithful baseline keeps the beyond-paper variants off."""
    for arch in ("mixtral-8x7b", "granite-34b"):
        cfg = get_config(arch)
        assert not cfg.hades.expert_gather_decode
        assert cfg.hades.kv_quant_bits == 16
