"""Scanned decode windows: `Server.decode_window` (W model steps + the
window-closing collect+backend as ONE jitted scan) must be BIT-identical
to W sequential `Server.decode_step`s — logits, pool bytes (scratch row
included), block tables, and collect reports — for both collector paths
(jnp oracle and Pallas interpret), both window shapes (aligned and
generic), and with the overlap_collect arm protocol on. Plus the
armed-window ATC semantics the double-buffered loop relies on, and the
`generate` e2e ride."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.models.model import build
from repro.runtime.server import Server, ServerConfig

B, EVERY = 2, 4
KW = dict(batch=B, max_len=32, block_tokens=4, collect_every=EVERY)

_MODELS = {}


def _model(arch="chatglm3-6b"):
    if arch not in _MODELS:
        m = build(arch, reduced=True)
        _MODELS[arch] = (m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _toks(m, t, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, m.cfg.vocab_size, (B, t)),
                       jnp.int32)


def _assert_state_equal(a, b):
    flat_a, _ = jax.tree_util.tree_flatten_with_path(a)
    flat_b = jax.tree_util.tree_leaves(b)
    for (path, x), y in zip(flat_a, flat_b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"kv state diverged at {jax.tree_util.keystr(path)}"


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("overlap", [False, True])
@pytest.mark.parametrize("arch,t", [("chatglm3-6b", 2 * EVERY),
                                    ("olmoe-1b-7b", EVERY + 2)])
def test_decode_window_matches_per_step(use_pallas, overlap, arch, t):
    """One window dispatch == t per-step dispatches, bit for bit: logits,
    sampled tokens, pool state (data incl. the scratch row, table, tiers,
    counters), and reports. t covers the cond-free window-aligned shape
    (2 windows) and the generic cond-gated shape (t % every != 0); the
    MoE arch covers the expert path inside the layer scan."""
    m, params = _model(arch)
    toks = _toks(m, t)
    cfg = ServerConfig(use_pallas=use_pallas, overlap_collect=overlap,
                       **KW)
    srv_a, srv_b = Server(m, cfg), Server(m, cfg)

    logits_a = jnp.stack(
        [srv_a.decode_step(params, toks[:, i])[0] for i in range(t)],
        axis=1)
    logits_b, sampled_b, rep = srv_b.decode_window(params, toks)

    assert np.array_equal(np.asarray(logits_a), np.asarray(logits_b))
    assert np.array_equal(np.asarray(jnp.argmax(logits_a, -1)),
                          np.asarray(sampled_b))
    _assert_state_equal(srv_a.state, srv_b.state)
    assert srv_a._steps == srv_b._steps
    # reports at window closers match the per-step path's float dicts
    reps_b = eng.window_reports(rep)
    assert len(reps_b) == t // EVERY
    assert srv_a.reports == reps_b
    # the whole window was ONE dispatch vs t
    assert (srv_a.dispatches, srv_b.dispatches) == (t, 1)


def test_decode_window_resumes_clock_across_calls():
    """Successive windows share the op clock: two decode_window calls of
    every//2 steps each close exactly one collect between them, same as
    the per-step path."""
    m, params = _model()
    toks = _toks(m, EVERY)
    srv = Server(m, ServerConfig(**KW))
    _, _, r1 = srv.decode_window(params, toks[:, :EVERY // 2])
    _, _, r2 = srv.decode_window(params, toks[:, EVERY // 2:])
    assert len(eng.window_reports(r1)) == 0
    assert len(eng.window_reports(r2)) == 1


def test_overlap_collect_armed_window_atc_semantics():
    """The epoch protocol under overlap: the window arms one step before
    closing, so every object the closing step dereferences carries
    ATC > 0 and is vetoed (skipped_atc > 0, nothing migrates, the armed
    flag is consumed by the collect). The synchronous window migrates the
    same objects freely."""
    m, params = _model()
    toks = _toks(m, 2 * EVERY)

    srv_sync = Server(m, ServerConfig(**KW))
    _, _, rep_s = srv_sync.decode_window(params, toks)
    rep_s = eng.window_reports(rep_s)

    srv_ovl = Server(m, ServerConfig(overlap_collect=True, **KW))
    _, _, rep_o = srv_ovl.decode_window(params, toks)
    rep_o = eng.window_reports(rep_o)

    # decode touches every live block each step, so with overlap all
    # would-be movers were dereferenced inside the armed epoch
    assert rep_o[0]["skipped_atc"] > 0
    assert rep_o[0]["moved_to_hot"] == 0
    assert rep_s[0]["skipped_atc"] == 0
    assert rep_s[0]["moved_to_hot"] > 0
    # the collect consumed the armed flag
    assert not bool(srv_ovl.state["pool"]["armed"])


@pytest.mark.parametrize("overlap", [False, True])
def test_generate_rides_windows(overlap):
    """`generate` == the manual per-step greedy loop, at O(tokens/W)
    dispatches; with overlap_collect the double-buffered report sync
    still surfaces every closed window exactly once, in order."""
    m, params = _model()
    prompts = _toks(m, 3, seed=1)
    max_new = 10                      # total steps 12 -> 3 collects

    srv_w = Server(m, ServerConfig(overlap_collect=overlap, **KW))
    out_w = srv_w.generate(params, prompts, max_new=max_new)

    srv_s = Server(m, ServerConfig(overlap_collect=overlap, **KW))
    tok = None
    outs = []
    for t in range(prompts.shape[1]):
        logits, _ = srv_s.decode_step(params, prompts[:, t])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs.append(tok)
    for _ in range(max_new - 1):
        logits, _ = srv_s.decode_step(params, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    out_s = jnp.stack(outs, axis=1)

    assert out_w.shape == (B, max_new)
    assert np.array_equal(np.asarray(out_w), np.asarray(out_s))
    assert srv_w.reports == srv_s.reports
    total = prompts.shape[1] + max_new - 1
    assert srv_w.dispatches == -(-total // EVERY)
    assert srv_s.dispatches == total


def test_paged_decode_matches_dense_decode():
    """The fixed single-phase server transition must reproduce the dense
    (ring-cache) decode path: each layer's k/v derives from the previous
    layer's output, and the appended token attends to itself — the seed's
    two-phase loop failed both."""
    m, params = _model()
    t = 6
    toks = _toks(m, t, seed=2)
    srv = Server(m, ServerConfig(**KW))
    dense_state = m.init_decode_state(B, t)
    for i in range(t):
        paged, _ = srv.decode_step(params, toks[:, i])
        dense, dense_state = m.decode_step(params, dense_state, toks[:, i])
        gap = float(jnp.abs(paged - dense).max())
        assert gap < 0.05, f"step {i}: paged/dense divergence {gap}"


def test_decode_past_max_len_drops_instead_of_corrupting():
    """Tokens past the pool's block capacity are DROPPED: an unguarded
    append would clamp the object id into the table and overwrite a LIVE
    block's bytes (another sequence's KV). Decoding past max_len must
    leave every in-capacity byte of the pool untouched."""
    m, params = _model()
    cap = 8                                   # 2 blocks of 4 per lane
    srv = Server(m, ServerConfig(batch=B, max_len=cap, block_tokens=4,
                                 collect_every=64))
    toks = _toks(m, cap + 3, seed=3)
    for i in range(cap):
        srv.decode_step(params, toks[:, i])
    data_at_cap = np.asarray(srv.state["pool"]["data"]).copy()
    for i in range(cap, cap + 3):
        logits, _ = srv.decode_step(params, toks[:, i])
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.array_equal(np.asarray(srv.state["pool"]["data"]),
                          data_at_cap), "overflow write corrupted the pool"
    assert int(srv.state["pos"][0]) == cap + 3


def test_generate_max_new_zero():
    """Degenerate request: no crash, empty output, no state change."""
    m, params = _model()
    srv = Server(m, ServerConfig(**KW))
    out = srv.generate(params, _toks(m, 3), max_new=0)
    assert out.shape == (B, 0)
    assert srv._steps == 0


def test_decode_window_seed_token_form():
    """decode_window(params, tok [B], w) == decode_window with an explicit
    [B, w] forced matrix of (seed, -1, ...) — the self-feeding window."""
    m, params = _model()
    seed = _toks(m, 1)[:, 0]
    srv_a, srv_b = Server(m, ServerConfig(**KW)), Server(m, ServerConfig(**KW))
    la, sa, _ = srv_a.decode_window(params, seed, w=EVERY)
    forced = jnp.concatenate(
        [seed[:, None], jnp.full((B, EVERY - 1), -1, jnp.int32)], axis=1)
    lb, sb, _ = srv_b.decode_window(params, forced)
    assert np.array_equal(np.asarray(la), np.asarray(lb))
    assert np.array_equal(np.asarray(sa), np.asarray(sb))
