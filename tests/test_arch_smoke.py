"""Per-architecture smoke tests: a REDUCED config of each family runs one
forward/train step (and a decode step) on CPU — output shapes + no NaNs.
The FULL configs are exercised only by the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, applicable, reduced_shape
from repro.models.model import build

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    m = build(arch, reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_inputs(reduced_shape("train_4k"), jax.random.PRNGKey(1))
    loss, aux = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # gradient flows
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    m = build(arch, reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    sh = reduced_shape("prefill_32k")
    batch = m.make_inputs(sh, jax.random.PRNGKey(1))
    logits = m.prefill(params, batch)
    assert logits.shape[0] == sh.global_batch
    assert logits.shape[-1] == m.cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    m = build(arch, reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    b = 2
    enc = None
    if m.cfg.is_encoder_decoder:
        enc = jnp.zeros((b, m.cfg.encoder_seq_len, m.cfg.d_model),
                        jnp.dtype(m.cfg.dtype))
    state = m.init_decode_state(b, 16, enc_out=enc)
    toks = jnp.asarray([1, 2], jnp.int32)
    for _ in range(4):
        logits, state = m.decode_step(params, state, toks)
    assert logits.shape == (b, m.cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state["pos"]) == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_logits(arch):
    """Teacher-forced decode must reproduce the full-forward logits
    (KV-cache correctness), for archs with exact step semantics.

    On failure the divergence is narrowed with a per-layer report of the
    residual-stream gap; for MoE archs whose prefill routing exceeded the
    per-expert capacity (tokens dropped by `moe_block`'s dispatch — a
    numeric artifact of the capacity-bounded grouped GEMM, NOT a KV-cache
    bug: decode's tiny per-step batch never overflows) the test xfails
    with the attribution instead of failing."""
    m = build(arch, reduced=True)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              m.cfg.vocab_size)
    enc = None
    batch = {"tokens": toks}
    if m.cfg.is_encoder_decoder:
        enc = jnp.zeros((b, m.cfg.encoder_seq_len, m.cfg.d_model),
                        jnp.float32)
        batch["enc_embeds"] = enc
    if m.cfg.frontend == "vision":
        pytest.skip("vlm prepends patches; decode parity not 1:1")
    full_logits, _ = m.forward(params, batch)
    state = m.init_decode_state(b, s, enc_out=(
        None if enc is None else enc.astype(jnp.dtype(m.cfg.dtype))))
    outs = []
    for t in range(s):
        lg, state = m.decode_step(params, state, toks[:, t])
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    err = jnp.abs(dec_logits - full_logits).max()

    if float(err) >= 0.15 and m.cfg.family not in ("ssm", "hybrid"):
        report, attributed = _per_layer_divergence_report(
            m, params, batch, b, s, enc)
        msg = f"{arch}: decode/prefill divergence {float(err):.4f}; {report}"
        if attributed:
            pytest.xfail(msg + " — attributed to MoE capacity drops in "
                         "prefill (decode path is drop-free)")
        pytest.fail(msg)
    assert float(err) < 0.15, f"{arch}: decode/prefill divergence {err}"


def _per_layer_divergence_report(m, params, batch, b, s, enc):
    """Compare the post-layer residual streams of prefill vs teacher-
    forced decode, layer by layer, and flag layers whose prefill MoE
    routing overflowed the per-expert capacity (dropped tokens).

    Returns (report, attributed): `attributed` is True only when the
    FIRST layer whose residual stream diverges is itself a capacity-
    dropped layer — a genuine KV-cache bug upstream of the MoE (attend /
    append) would surface at a clean layer and must still FAIL, not
    xfail."""
    from repro.models import moe as moe_lib
    from repro.models import transformer as T
    _, aux = T.lm_forward(params, m.cfg, batch["tokens"],
                          enc_embeds=batch.get("enc_embeds"),
                          return_hiddens=True)
    hs_full = np.asarray(aux["hiddens"], np.float32)      # [L,B,S,D]
    state = m.init_decode_state(b, s, enc_out=(
        None if enc is None else enc.astype(jnp.dtype(m.cfg.dtype))))
    hs_dec = []
    for t in range(s):
        _, state, hs = m.decode_step(params, state, batch["tokens"][:, t],
                                     return_hiddens=True)
        hs_dec.append(np.asarray(hs, np.float32))          # [L,B,1,D]
    hs_dec = np.concatenate(hs_dec, axis=2)                # [L,B,S,D]
    gaps = np.abs(hs_full - hs_dec).max(axis=(1, 2, 3))    # [L]

    overflow = np.zeros(len(gaps), bool)
    if m.cfg.num_experts and "expert_counts_per_layer" in aux:
        g = moe_lib.capacity(b * s, m.cfg)
        counts = np.asarray(aux["expert_counts_per_layer"])  # [L,E]
        overflow = (counts > g).any(axis=1)
    lines = [f"L{li}: dh={gaps[li]:.4f}"
             + (" capacity-dropped" if overflow[li] else "")
             for li in range(len(gaps))]
    report = "per-layer residual gap [" + "; ".join(lines) + "]"
    diverged = gaps > max(1e-3, 0.02 * float(gaps.max()))
    first = int(np.argmax(diverged)) if diverged.any() else -1
    attributed = first >= 0 and bool(overflow[first])
    return report, attributed


def test_long_500k_applicability_matrix():
    runnable = {a: applicable(get_config(a), "long_500k")[0]
                for a in ARCHS}
    assert runnable["falcon-mamba-7b"]           # ssm
    assert runnable["zamba2-2.7b"]               # hybrid
    assert runnable["mixtral-8x7b"]              # SWA
    for a in ("glm4-9b", "granite-20b", "granite-34b", "chatglm3-6b",
              "olmoe-1b-7b", "qwen2-vl-72b", "seamless-m4t-large-v2"):
        assert not runnable[a], f"{a} should skip long_500k"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {"mixtral-8x7b": 46e9, "olmoe-1b-7b": 6.9e9,
                "qwen2-vl-72b": 72e9, "glm4-9b": 9e9,
                "granite-20b": 20e9, "granite-34b": 34e9,
                "chatglm3-6b": 6e9, "zamba2-2.7b": 2.7e9,
                "falcon-mamba-7b": 7e9,
                "seamless-m4t-large-v2": 2.3e9}[arch]
    assert 0.6 * expected < n < 1.6 * expected, \
        f"{arch}: {n/1e9:.1f}B params vs published ~{expected/1e9:.0f}B"
