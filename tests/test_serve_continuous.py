"""Continuous batching through the fused serving windows: lane
lifecycle (admit -> decode -> finish -> free -> refill), in-scan
sampling, and the pool invariants under lane churn.

The load-bearing contracts:
  * `Server.serve` sustains churn at exactly ONE dispatch per window,
    with lane events (free finished lanes' KV through the pool op
    stream, admit from the queue) resolved at window boundaries INSIDE
    the window dispatch (`engine.window_program`'s pre_fn plumbing);
  * a finished lane's freed slots return to the free rings with the
    carried allocator state consistent (`check_freelist`), and a
    refilled lane decodes bit-identically to a fresh server on the same
    prompt;
  * `generate`'s sampling params are live: greedy stays bit-identical
    to the pre-sampler path, `greedy=False` without a key refuses
    instead of silently decoding greedily."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import kvcache as kvc
from repro.models.model import build
from repro.runtime.server import Completion, Request, Server, ServerConfig
from test_pool_collector import check_freelist

B, W = 2, 4
KW = dict(batch=B, max_len=32, block_tokens=4, collect_every=W, window=W)

_MODELS = {}


def _model(arch="chatglm3-6b"):
    if arch not in _MODELS:
        m = build(arch, reduced=True)
        _MODELS[arch] = (m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (n,)).tolist()


# ---------------------------------------------------------------------------
# lane free/refill pool invariants
# ---------------------------------------------------------------------------
def test_free_lanes_returns_slots_to_rings():
    """After a lane finishes, every slot it owned returns to the free
    rings: counts restored, sb_occ/slot_ref consistent (the carried
    allocator state never drifts — check_freelist oracle)."""
    m, params = _model()
    srv = Server(m, ServerConfig(**KW))
    pcfg = srv.kv_cfg.pool_config()
    free0 = int(jnp.sum(srv.state["pool"]["free_count"]))

    prompts = jnp.asarray(np.random.default_rng(3).integers(
        0, m.cfg.vocab_size, (B, 3)), jnp.int32)
    srv.generate(params, prompts, max_new=6)
    used = int(jnp.sum(srv.state["block_tables"] >= 0))
    assert used > 0
    assert int(jnp.sum(srv.state["pool"]["free_count"])) == free0 - used
    check_freelist(srv.state["pool"], cfg=pcfg)

    # finish lane 0 through the op stream; lane 1 keeps its KV
    lane0 = jnp.asarray([True, False])
    state = jax.jit(lambda s: kvc.free_lanes(srv.kv_cfg, s, lane0))(
        srv.state)
    freed = used - int(jnp.sum(state["block_tables"] >= 0))
    assert freed > 0
    assert int(jnp.sum(state["pool"]["free_count"])) == \
        free0 - used + freed, "freed slots did not return to the rings"
    check_freelist(state["pool"], cfg=pcfg)
    assert not bool(state["active"][0]) and bool(state["active"][1])
    assert int(state["pos"][0]) == 0 and int(state["pos"][1]) > 0

    # free is idempotent at the op level: dead ids drop
    state2 = jax.jit(lambda s: kvc.free_lanes(srv.kv_cfg, s, lane0))(state)
    assert int(jnp.sum(state2["pool"]["free_count"])) == \
        int(jnp.sum(state["pool"]["free_count"]))
    check_freelist(state2["pool"], cfg=pcfg)

    # the freed (inactive) lane's attend returns ZEROS — not a masked
    # softmax degenerating to a neighbor lane's payload mean
    q = jnp.ones((B, m.cfg.num_heads, m.cfg.resolved_head_dim),
                 jnp.float32)
    out, _ = kvc.attend(srv.kv_cfg, state2, 0, q)
    assert bool(jnp.all(out[0] == 0)), "inactive lane leaked KV data"
    assert bool(jnp.any(out[1] != 0))


def test_serve_one_dispatch_per_window_and_drains_pool():
    """Lane churn (more requests than lanes) at exactly 1 dispatch per
    window; the drain window frees the last lanes' KV through the op
    stream, so the pool ends empty and the allocator state consistent."""
    m, params = _model()
    srv = Server(m, ServerConfig(**KW))
    reqs = [Request(prompt=_prompt(3, 1), max_new=5),
            Request(prompt=_prompt(2, 2), max_new=9),
            Request(prompt=_prompt(4, 3), max_new=3)]
    results = srv.serve(params, reqs)
    assert srv.dispatches == len(srv.serve_log) > 0
    assert all(isinstance(r, Completion) for r in results)
    assert [len(r.tokens) for r in results] == [5, 9, 3]
    # the third request could only run on a refilled lane
    assert results[2].windows[0] > 0
    # drained: no live objects, all slots back on the rings, RSS zero;
    # the server hands back the fixed-batch contract (lanes active,
    # clocks reset) for later generate/decode_step use
    assert int(jnp.sum(srv.state["block_tables"] >= 0)) == 0
    assert bool(jnp.all(srv.state["active"]))
    assert int(jnp.sum(srv.state["pos"])) == 0
    assert srv.kv_rss_bytes() == 0.0
    check_freelist(srv.state["pool"], cfg=srv.kv_cfg.pool_config())
    # RSS tracked the churn down: peak > final
    rss = [e["rss_bytes"] for e in srv.serve_log]
    assert max(rss) > rss[-1] == 0.0


@pytest.mark.parametrize("overlap", [False, True])
def test_refilled_lane_bit_identical_to_fresh_server(overlap):
    """A refilled lane (slots reused from a freed predecessor, pool
    shared with a live neighbor) decodes bit-identically to a fresh
    server decoding the same prompt — migration-transparent pointer
    dereferences + per-lane pos make lane history invisible."""
    m, params = _model()
    prompt_c = _prompt(3, 7)
    # lane churn: rid0 finishes fast -> its lane refills with rid2
    reqs = [Request(prompt=_prompt(2, 5), max_new=2),
            Request(prompt=_prompt(4, 6), max_new=14),
            Request(prompt=prompt_c, max_new=6)]
    srv = Server(m, ServerConfig(overlap_collect=overlap, **KW))
    res = srv.serve(params, reqs)
    assert res[2].windows[0] > 0, "rid2 was not a refill"

    fresh = Server(m, ServerConfig(overlap_collect=overlap, **KW))
    ref = fresh.serve(params, [Request(prompt=prompt_c, max_new=6)])
    assert res[2].tokens == ref[0].tokens
    assert res[2].finish_reason == ref[0].finish_reason


def test_serve_eos_finishes_lane():
    """A sampled EOS retires the request at the window boundary with
    finish_reason 'eos' (the EOS token itself is the last output)."""
    m, params = _model()
    probe = Server(m, ServerConfig(**KW))
    first = int(probe.serve(params,
                            [Request(prompt=_prompt(3, 9),
                                     max_new=1)])[0].tokens[0])
    srv = Server(m, ServerConfig(eos_token=first, **KW))
    res = srv.serve(params, [Request(prompt=_prompt(3, 9), max_new=8)])
    assert res[0].finish_reason == "eos"
    assert res[0].tokens[-1] == first
    assert len(res[0].tokens) < 8


def test_serve_caps_at_lane_capacity():
    """A request whose prompt+output would overrun max_len finishes
    with 'length' at the capacity instead of decoding dropped tokens."""
    m, params = _model()
    cap = 8
    srv = Server(m, ServerConfig(batch=B, max_len=cap, block_tokens=4,
                                 collect_every=W, window=W))
    res = srv.serve(params, [Request(prompt=_prompt(3, 4), max_new=50)])
    assert res[0].finish_reason == "length"
    assert len(res[0].tokens) == cap - 3 + 1  # steps 2..7 emit outputs


# ---------------------------------------------------------------------------
# in-scan sampling
# ---------------------------------------------------------------------------
def test_generate_nongreedy_requires_key():
    """greedy=False without a key must refuse — it used to silently
    decode greedily (the dead-parameter bug)."""
    m, params = _model()
    srv = Server(m, ServerConfig(**KW))
    prompts = jnp.zeros((B, 2), jnp.int32)
    with pytest.raises(ValueError, match="PRNG"):
        srv.generate(params, prompts, max_new=2, greedy=False)


def test_generate_sampled_reproducible_and_distinct():
    """Sampling runs in-scan off the carried key: same key -> identical
    stream, different key -> different stream; greedy output is
    unaffected by the sampler riding the carry."""
    m, params = _model()
    prompts = jnp.asarray(np.random.default_rng(11).integers(
        0, m.cfg.vocab_size, (B, 3)), jnp.int32)
    cfg = ServerConfig(temperature=1.5, top_k=8, **KW)
    srv = Server(m, cfg)
    out_greedy = srv.generate(params, prompts, max_new=8)
    srv.reset()
    s1 = srv.generate(params, prompts, max_new=8, greedy=False,
                      key=jax.random.PRNGKey(1))
    srv.reset()
    s2 = srv.generate(params, prompts, max_new=8, greedy=False,
                      key=jax.random.PRNGKey(1))
    srv.reset()
    s3 = srv.generate(params, prompts, max_new=8, greedy=False,
                      key=jax.random.PRNGKey(2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert not np.array_equal(np.asarray(s1), np.asarray(s3))
    assert s1.shape == out_greedy.shape
    assert bool(jnp.all((s1 >= 0) & (s1 < m.cfg.vocab_size)))
    # greedy on the same server object still matches a fresh greedy run
    srv.reset()
    again = srv.generate(params, prompts, max_new=8)
    assert np.array_equal(np.asarray(again), np.asarray(out_greedy))


def test_sampled_lanes_top_k_support():
    """top_k restricts every sampled token to that step's k best logits
    (checked against the per-step teacher-forced logits)."""
    m, params = _model()
    prompts = jnp.asarray(np.random.default_rng(13).integers(
        0, m.cfg.vocab_size, (B, 2)), jnp.int32)
    k = 4
    srv = Server(m, ServerConfig(temperature=2.0, top_k=k, **KW))
    out = srv.generate(params, prompts, max_new=6, greedy=False,
                       key=jax.random.PRNGKey(5))
    # replay the sampled stream teacher-forced through a fresh server to
    # recover each step's logits, then check membership in its top-k
    replay = Server(m, ServerConfig(**KW))
    forced = jnp.concatenate([prompts, out[:, :-1]], axis=1)
    logits, _, _ = replay.decode_window(params, forced)
    steps = logits[:, prompts.shape[1] - 1:]            # [B, 6, V]
    topk_ids = jnp.argsort(steps, axis=-1)[..., -k:]
    for b in range(B):
        for t in range(out.shape[1]):
            assert int(out[b, t]) in np.asarray(topk_ids[b, t]), \
                f"lane {b} step {t}: sampled outside top-{k}"


def test_serve_rejects_oversized_or_empty_prompts():
    """Prompts that cannot fit a lane refuse at submission — KV appends
    past capacity silently drop, so decoding them would condition on a
    truncated prompt."""
    m, params = _model()
    srv = Server(m, ServerConfig(**KW))
    with pytest.raises(ValueError, match="prompt length"):
        srv.serve(params, [Request(prompt=_prompt(KW["max_len"], 1),
                                   max_new=2)])
    with pytest.raises(ValueError, match="prompt length"):
        srv.serve(params, [Request(prompt=[], max_new=2)])
    with pytest.raises(ValueError, match="max_new"):
        srv.serve(params, [Request(prompt=[1, 2], max_new=0)])


def test_generate_after_serve_reuses_the_server():
    """serve hands the server back in the fixed-batch contract: a
    subsequent generate decodes on live lanes (bit-identical to a fresh
    server), not on the drained serve masks."""
    m, params = _model()
    prompts = jnp.asarray(np.random.default_rng(17).integers(
        0, m.cfg.vocab_size, (B, 3)), jnp.int32)
    srv = Server(m, ServerConfig(**KW))
    srv.serve(params, [Request(prompt=_prompt(3, 15), max_new=4)])
    out = srv.generate(params, prompts, max_new=5)
    fresh = Server(m, ServerConfig(**KW))
    ref = fresh.generate(params, prompts, max_new=5)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
