"""Page Utilization metric: bounds, exactness, fragmentation sensitivity
(invariant 6)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import collector as col
from repro.core import page_util
from repro.core import pool as pl


def test_exact_cases():
    # one 64-byte access on one 4096-byte page
    assert abs(page_util.from_arrays(np.asarray([0]), np.asarray([64]))
               - 64 / 4096) < 1e-9
    # full page
    assert abs(page_util.from_arrays(np.asarray([0]), np.asarray([4096]))
               - 1.0) < 1e-9
    # overlapping records dedup (unique bytes)
    pu = page_util.from_arrays(np.asarray([0, 32]), np.asarray([64, 64]))
    assert abs(pu - 96 / 4096) < 1e-9
    # spanning a page boundary counts both pages
    pu = page_util.from_arrays(np.asarray([4000]), np.asarray([200]))
    assert abs(pu - 200 / 8192) < 1e-9


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1 << 20), st.integers(1, 4096)),
                min_size=1, max_size=100))
def test_bounds(records):
    addrs = np.asarray([a for a, _ in records])
    sizes = np.asarray([s for _, s in records])
    pu = page_util.from_arrays(addrs, sizes)
    assert 0.0 < pu <= 1.0


def test_fragmented_vs_dense():
    """The metric's whole point: same bytes, scattered -> low PU."""
    n, sz = 64, 64
    dense = page_util.from_arrays(np.arange(n) * sz,
                                  np.full(n, sz))
    scattered = page_util.from_arrays(np.arange(n) * 4096,
                                      np.full(n, sz))
    assert dense == 1.0
    assert scattered == sz / 4096
    assert dense / scattered == 4096 / sz


def test_pool_variant_improves_after_tidying():
    """HADES never decreases PU on a stationary workload (statistical,
    fixed seed)."""
    cfg = pl.make_config(max_objects=128, slot_words=4, sb_slots=16,
                         page_slots=4, slack=2.0)
    state = pl.init(cfg)
    rng = np.random.default_rng(0)
    vals = jnp.zeros((128, 4), jnp.float32)
    state = pl.alloc(cfg, state, jnp.arange(128, dtype=jnp.int32), vals)
    hot = rng.permutation(128)[:16]                # scattered hot set
    ccfg = col.CollectorConfig()
    # clear the alloc-time access bits (they make PU trivially 1.0)
    state, _ = col.collect(cfg, ccfg, state)
    _, state = pl.read(cfg, state, jnp.asarray(hot, jnp.int32))
    pu0 = float(page_util.from_pool(cfg, state))   # fragmented layout
    for _ in range(4):
        state, _ = col.collect(cfg, ccfg, state)
        _, state = pl.read(cfg, state, jnp.asarray(hot, jnp.int32))
    pu1 = float(page_util.from_pool(cfg, state))   # tidied layout
    assert 0 < pu0 <= 1 and 0 < pu1 <= 1
    assert pu1 >= pu0, f"tidying decreased page utilization {pu0}->{pu1}"
