"""Serving example: batched decode through the HADES-managed paged KV
cache — watch the Object Collector demote cold prefix blocks while
generation continues uninterrupted.

    PYTHONPATH=src python examples/serve_kv.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build
from repro.runtime.server import Server, ServerConfig

model = build("chatglm3-6b", reduced=True)
params = model.init(jax.random.PRNGKey(0))

srv = Server(model, ServerConfig(batch=4, max_len=96, block_tokens=8,
                                 collect_every=12, backend="proactive"))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, model.cfg.vocab_size, (4, 6)),
                      jnp.int32)
print("decoding 48 tokens for 4 requests...")
out = srv.generate(params, prompts, max_new=48)
print(f"generated: {out.shape}")
print(f"KV RSS: {srv.kv_rss_bytes()/2**10:.0f} KiB of "
      f"{srv.kv_cfg.max_objects * srv.kv_cfg.slot_words * 2 / 2**10:.0f} "
      f"KiB allocated")
print("\ncollector reports (promotion rate / moves / threshold):")
for i, r in enumerate(srv.reports):
    print(f"  window {i}: promo={r['promotion_rate']:.3f} "
          f"hot+={r['moved_to_hot']:.0f} cold+={r['moved_to_cold']:.0f} "
          f"C_t={r['ciw_threshold']:.0f} rss={r['rss_bytes']/2**10:.0f}KiB")
