"""End-to-end training driver: a ~100M-param GLM-family model trained
for a few hundred steps with the full production stack — deterministic
zipfian data, AdamW + cosine schedule, async atomic checkpointing,
straggler monitoring, preemption-safe shutdown.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(Same loop `python -m repro.launch.train --arch <id>` runs on a real
pod; this example sizes the model to CPU.)
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.lm import DataConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: glm4 family scaled to 12L x 768
    cfg = dataclasses.replace(
        get_config("glm4-9b"),
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=2,
        d_ff=2048, vocab_size=32768, head_dim=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} scaled -> {n_params/1e6:.1f}M params")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                      global_batch=8)
    opt = AdamWConfig(lr=6e-4, total_steps=args.steps,
                      warmup_steps=max(args.steps // 10, 10))
    run = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                        log_every=10)
    trainer = Trainer(model, data, opt, run)
    trainer.install_signal_handlers()

    def log(step, m):
        print(f"step {step:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
              f"{m['step_time_s']*1e3:.0f} ms")

    out = trainer.run(params, args.steps, on_metrics=log)
    first = out["history"][0][1]["loss"]
    last = out["history"][-1][1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {out['step']} steps; "
          f"stragglers flagged: {len(out['stragglers'])}; "
          f"checkpoints in {args.ckpt_dir}")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
