"""The paper's experiment in one script: CrestKV + YCSB-C, baseline vs
HADES, on any of the ten Table-1 structures.

    PYTHONPATH=src python examples/ycsb_crestkv.py [--structure masstree]
"""
import argparse

from repro.data.crestkv import CrestKV, default_sim_config


def run(structure: str, enabled: bool, backend: str, n_keys: int):
    cfg = default_sim_config(n_keys, backend=backend, enabled=enabled)
    kv = CrestKV(structure, n_keys, cfg, seed=0)
    stats = kv.run("C", n_ops=n_keys * 40, window_ops=n_keys * 2, seed=1)
    last = stats.windows[-1]
    return {
        "page_util": last["page_utilization"],
        "rss_mib": last["rss_bytes"] / 2 ** 20,
        "overhead_pct": stats.overhead_frac * 100,
        "faults": stats.faults,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--structure", default="hash-pugh")
    ap.add_argument("--keys", type=int, default=100_000)
    args = ap.parse_args()

    print(f"structure={args.structure}, {args.keys} keys, YCSB-C "
          f"(zipfian, active ~1/3, scattered)\n")
    base = run(args.structure, enabled=False, backend="null",
               n_keys=args.keys)
    hades = run(args.structure, enabled=True, backend="proactive",
                n_keys=args.keys)
    print(f"{'':16s}{'baseline':>12s}{'HADES':>12s}")
    print(f"{'page util':16s}{base['page_util']:>12.2f}"
          f"{hades['page_util']:>12.2f}")
    print(f"{'rss (MiB)':16s}{base['rss_mib']:>12.1f}"
          f"{hades['rss_mib']:>12.1f}")
    print(f"{'overhead (%)':16s}{base['overhead_pct']:>12.2f}"
          f"{hades['overhead_pct']:>12.2f}")
    print(f"{'faults':16s}{base['faults']:>12d}{hades['faults']:>12d}")
    red = 1 - hades["rss_mib"] / base["rss_mib"]
    print(f"\nmemory reduction: {red*100:.0f}%  "
          f"(paper: up to 70%)")


if __name__ == "__main__":
    main()
