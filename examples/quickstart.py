"""Quickstart: tidy up an address space in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Allocates 512 objects, hammers a scattered hot subset, and watches the
HADES frontend reorganize the heap: page utilization climbs, the cold
superblocks leave HBM, and reads still return the right bytes.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import Hades, HadesOptions, make_config
from repro.core import backend

# a pool of 512 objects x 32 floats, superblock = 16 slots.
# Backends come from the registry: backend.make(name, **params) — any of
# backend.names() ('cap', 'mglru', 'null', 'proactive', 'promote',
# 'reactive'); stateful ones (mglru, promote) carry their state across
# windows automatically.
cfg = make_config(max_objects=512, slot_words=32, sb_slots=16,
                  page_slots=4, slack=2.0)
h = Hades(cfg, HadesOptions(collect_every=4,
                            backend=backend.make("proactive")))

ids = np.arange(512)
vals = jnp.arange(512 * 32, dtype=jnp.float32).reshape(512, 32)
h.alloc(ids, vals)
h.end_load_phase()                       # load stores != workload accesses
print(f"allocated: {h.heap_histogram()}  rss={h.rss_bytes()//1024} KiB")

rng = np.random.default_rng(0)
hot = rng.permutation(512)[:48]          # scattered hot set
for step in range(96):
    got = h.read(hot[rng.integers(0, 48, size=16)])

print(f"after tidying: {h.heap_histogram()}")
print(f"rss={h.rss_bytes()//1024} KiB  host={h.host_bytes()//1024} KiB  "
      f"page_util={h.page_utilization():.2f}")
print(f"counters: {h.counters()}")

# correctness: every object still reads back its exact bytes
all_back = h.read(ids)
assert np.allclose(np.asarray(all_back), np.asarray(vals))
print("content preserved after", h.counters()["moves"], "migrations ✓")
